//! Serving metrics: the quantities §5 reports.
//!
//! * **Normalized latency** — median over requests of (end-to-end latency
//!   minus intercepted time) / output tokens (ms/token).
//! * **Throughput** — finished requests per second.
//! * **TTFT** — arrival to first generated token.
//! * **GPU waste** — GB·s of memory held/consumed without producing tokens,
//!   broken down by cause (preserve hold, recompute rebuild, swap stall) —
//!   the paper's §3.2 accounting.
//! * **Recompute share** — fraction of forward time spent re-processing
//!   previously computed tokens (the 37–40% claim).

use crate::kvcache::ReqId;
use crate::util::{stats, to_secs, Micros};

/// Per-request record, filled as the request progresses.
#[derive(Debug, Clone)]
pub struct RequestRecord {
    pub req: ReqId,
    pub arrival: Micros,
    pub first_token_at: Option<Micros>,
    pub finished_at: Option<Micros>,
    pub intercepted_us: Micros,
    pub output_tokens: usize,
    pub interceptions: usize,
}

impl RequestRecord {
    /// (E2E − intercepted) / output tokens, in ms per token.
    pub fn normalized_latency_ms(&self) -> Option<f64> {
        let fin = self.finished_at?;
        let serve_us = (fin - self.arrival).saturating_sub(self.intercepted_us);
        if self.output_tokens == 0 {
            return None;
        }
        Some(serve_us as f64 / 1e3 / self.output_tokens as f64)
    }

    pub fn ttft_ms(&self) -> Option<f64> {
        Some((self.first_token_at? - self.arrival) as f64 / 1e3)
    }
}

/// GPU-memory waste accounting in GB·s by cause.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct WasteBreakdown {
    /// Paused requests' GPU-resident context × time.
    pub preserve_gbs: f64,
    /// Memory being rebuilt by recomputation × time.
    pub recompute_gbs: f64,
    /// All resident context × stall time (sync swap, over-budget transfers).
    pub stall_gbs: f64,
}

impl WasteBreakdown {
    pub fn total(&self) -> f64 {
        self.preserve_gbs + self.recompute_gbs + self.stall_gbs
    }
}

/// Rolling accumulator the engine feeds each iteration.
#[derive(Debug, Default)]
pub struct Recorder {
    pub records: Vec<RequestRecord>,
    pub waste: WasteBreakdown,
    pub iterations: u64,
    pub compute_us: Micros,
    pub stall_us: Micros,
    /// Query-token counts by kind.
    pub decode_tokens: u64,
    pub prefill_tokens: u64,
    pub recompute_tokens: u64,
    /// Forward time attributed to recomputation (token-weighted).
    pub recompute_fwd_us: f64,
    /// Time during which paused requests held ≥ half the GPU pool.
    pub paused_majority_us: Micros,
    pub swapped_out_tokens: u64,
    pub swapped_in_tokens: u64,
    pub evictions: u64,
    /// Per-stage disposition decisions (§4.3), one count per paused request
    /// per iteration the planner acted on it.
    pub preserve_decisions: u64,
    pub discard_decisions: u64,
    pub swap_decisions: u64,
    /// Interception lifecycle: fired / resolved (any origin), and the
    /// subset resolved externally by clients (serving front sessions).
    pub interceptions_dispatched: u64,
    pub interceptions_resolved: u64,
    pub external_interceptions: u64,
    /// Client-supplied resumption tokens dropped because they would have
    /// pushed the context past the submit-time capacity guarantee.
    pub clamped_resume_tokens: u64,
    /// Session-lifecycle teardowns: sessions cancelled (client aborts plus
    /// deadline-cancels), external interceptions that hit their deadline
    /// (whatever the timeout action), and submissions rejected by
    /// backpressure (`SubmitError::AtCapacity`).
    pub sessions_cancelled: u64,
    pub interceptions_timed_out: u64,
    pub submits_rejected: u64,
    /// Interception failure semantics (see `crate::engine` module docs):
    /// dispatch attempts that completed as failures, re-dispatches issued
    /// by the retry machinery, and exhausted-retry terminals resolved by a
    /// non-cancel [`crate::config::FailureAction`] (empty or scripted
    /// fallback answer). All zero in a fault-free run.
    pub interception_failures: u64,
    pub interception_retries: u64,
    pub interception_fallbacks: u64,
    /// O(batch) iteration gauges: dirty ids consumed by the incremental
    /// snapshot captures (Σ over iterations), waiting-queue entries
    /// materialized by the admission frontier (Σ over iterations), and
    /// channel sends saved by token-event coalescing.
    pub capture_dirty_ids: u64,
    pub frontier_depth: u64,
    pub events_batched: u64,
    /// Prefix-sharing gauges: sessions admitted by forking a cached prefix
    /// (`prefix_hits`), copy-on-write block copies triggered by writes into
    /// shared blocks (`cow_copies`, cumulative), and the peak number of
    /// physical GPU blocks simultaneously aliased by ≥ 2 sequences
    /// (`blocks_shared`). All zero when sharing is unused.
    pub prefix_hits: u64,
    pub cow_copies: u64,
    pub blocks_shared: u64,
    /// Speculative-continuation gauges (see `crate::speculation`): branches
    /// forked at interception dispatch, how they resolved, and the token
    /// economics — `speculative_tokens_decoded` = every token a branch
    /// decoded, of which `..._salvaged` survived verification into the
    /// parent (context the resume did *not* recompute) and `..._wasted`
    /// were discarded with the branch. All zero when `--speculate` is off.
    pub speculations_started: u64,
    pub speculations_accepted: u64,
    pub speculations_rejected: u64,
    pub speculative_tokens_decoded: u64,
    pub speculative_tokens_salvaged: u64,
    pub speculative_tokens_wasted: u64,
    pub run_started: Micros,
    pub run_ended: Micros,
}

impl Recorder {
    pub fn finish_request(&mut self, rec: RequestRecord) {
        self.records.push(rec);
    }

    /// Per-iteration accrual. `dt_us = compute + stall`; `recompute_us` is
    /// the engine's marginal-cost attribution of recompute time.
    #[allow(clippy::too_many_arguments)]
    pub fn iteration(
        &mut self,
        compute_us: Micros,
        stall_us: Micros,
        decode_q: usize,
        prefill_q: usize,
        recompute_q: usize,
        recompute_us: f64,
    ) {
        self.iterations += 1;
        self.compute_us += compute_us;
        self.stall_us += stall_us;
        self.decode_tokens += decode_q as u64;
        self.prefill_tokens += prefill_q as u64;
        self.recompute_tokens += recompute_q as u64;
        self.recompute_fwd_us += recompute_us;
    }

    /// Fraction of total forward time spent on recomputation.
    pub fn recompute_fwd_fraction(&self) -> f64 {
        if self.compute_us == 0 {
            0.0
        } else {
            self.recompute_fwd_us / self.compute_us as f64
        }
    }

    /// Like [`Recorder::report`], but valid mid-run: the duration runs to
    /// `now` when the run has not ended yet (a drained run's `run_ended`
    /// equals the final clock, so this is identical after completion).
    pub fn report_as_of(&self, now: Micros, policy: &str, label: &str) -> RunReport {
        let mut rep = self.report(policy, label);
        rep.duration_s = to_secs(self.run_ended.max(now).saturating_sub(self.run_started));
        rep
    }

    pub fn report(&self, policy: &str, label: &str) -> RunReport {
        RunReport {
            policy: policy.to_string(),
            label: label.to_string(),
            completed: self.records.iter().filter(|r| r.finished_at.is_some()).count(),
            total: self.records.len(),
            duration_s: to_secs(self.run_ended.saturating_sub(self.run_started)),
            norm_latencies_ms: self
                .records
                .iter()
                .filter_map(|r| r.normalized_latency_ms())
                .collect(),
            ttfts_ms: self.records.iter().filter_map(|r| r.ttft_ms()).collect(),
            waste: self.waste,
            iterations: self.iterations,
            compute_s: to_secs(self.compute_us),
            stall_s: to_secs(self.stall_us),
            recompute_fwd_fraction: self.recompute_fwd_fraction(),
            paused_majority_s: to_secs(self.paused_majority_us),
            swapped_out_tokens: self.swapped_out_tokens,
            swapped_in_tokens: self.swapped_in_tokens,
            evictions: self.evictions,
            preserve_decisions: self.preserve_decisions,
            discard_decisions: self.discard_decisions,
            swap_decisions: self.swap_decisions,
            interceptions_dispatched: self.interceptions_dispatched,
            interceptions_resolved: self.interceptions_resolved,
            external_interceptions: self.external_interceptions,
            sessions_cancelled: self.sessions_cancelled,
            interceptions_timed_out: self.interceptions_timed_out,
            submits_rejected: self.submits_rejected,
            interception_failures: self.interception_failures,
            interception_retries: self.interception_retries,
            interception_fallbacks: self.interception_fallbacks,
            capture_dirty_ids: self.capture_dirty_ids,
            frontier_depth: self.frontier_depth,
            events_batched: self.events_batched,
            prefix_hits: self.prefix_hits,
            cow_copies: self.cow_copies,
            blocks_shared: self.blocks_shared,
            speculations_started: self.speculations_started,
            speculations_accepted: self.speculations_accepted,
            speculations_rejected: self.speculations_rejected,
            speculative_tokens_decoded: self.speculative_tokens_decoded,
            speculative_tokens_salvaged: self.speculative_tokens_salvaged,
            speculative_tokens_wasted: self.speculative_tokens_wasted,
        }
    }
}

/// Final aggregate for one run — what every experiment binary prints.
#[derive(Debug, Clone)]
pub struct RunReport {
    pub policy: String,
    pub label: String,
    pub completed: usize,
    pub total: usize,
    pub duration_s: f64,
    pub norm_latencies_ms: Vec<f64>,
    pub ttfts_ms: Vec<f64>,
    pub waste: WasteBreakdown,
    pub iterations: u64,
    pub compute_s: f64,
    pub stall_s: f64,
    pub recompute_fwd_fraction: f64,
    pub paused_majority_s: f64,
    pub swapped_out_tokens: u64,
    pub swapped_in_tokens: u64,
    pub evictions: u64,
    /// Per-stage disposition decision counts (preserve / discard / swap).
    pub preserve_decisions: u64,
    pub discard_decisions: u64,
    pub swap_decisions: u64,
    /// Interception lifecycle counts (see [`Recorder`]).
    pub interceptions_dispatched: u64,
    pub interceptions_resolved: u64,
    pub external_interceptions: u64,
    /// Session-lifecycle counts (see [`Recorder`]).
    pub sessions_cancelled: u64,
    pub interceptions_timed_out: u64,
    pub submits_rejected: u64,
    /// Interception failure-semantics counts (see [`Recorder`]).
    pub interception_failures: u64,
    pub interception_retries: u64,
    pub interception_fallbacks: u64,
    /// O(batch) iteration gauges (see [`Recorder`]).
    pub capture_dirty_ids: u64,
    pub frontier_depth: u64,
    pub events_batched: u64,
    /// Prefix-sharing gauges (see [`Recorder`]).
    pub prefix_hits: u64,
    pub cow_copies: u64,
    pub blocks_shared: u64,
    /// Speculative-continuation gauges (see [`Recorder`]).
    pub speculations_started: u64,
    pub speculations_accepted: u64,
    pub speculations_rejected: u64,
    pub speculative_tokens_decoded: u64,
    pub speculative_tokens_salvaged: u64,
    pub speculative_tokens_wasted: u64,
}

impl RunReport {
    /// Median normalized latency, ms per output token (§5.1's headline).
    pub fn normalized_latency_ms(&self) -> f64 {
        stats::median(&self.norm_latencies_ms)
    }

    pub fn p99_normalized_latency_ms(&self) -> f64 {
        stats::percentile_of(&self.norm_latencies_ms, 99.0)
    }

    /// Finished requests per second.
    pub fn throughput_rps(&self) -> f64 {
        if self.duration_s == 0.0 {
            0.0
        } else {
            self.completed as f64 / self.duration_s
        }
    }

    pub fn median_ttft_ms(&self) -> f64 {
        stats::median(&self.ttfts_ms)
    }

    /// Fraction of speculatively decoded tokens that survived verification
    /// into their parent session (0.0 when speculation never ran).
    pub fn speculation_salvage_ratio(&self) -> f64 {
        if self.speculative_tokens_decoded == 0 {
            0.0
        } else {
            self.speculative_tokens_salvaged as f64 / self.speculative_tokens_decoded as f64
        }
    }

    pub fn p99_ttft_ms(&self) -> f64 {
        stats::percentile_of(&self.ttfts_ms, 99.0)
    }

    pub fn summary_line(&self) -> String {
        format!(
            "{:<20} {:>5}/{:<5} done  norm-lat {:>9.2} ms/tok  ttft {:>9.1} ms  \
             thru {:>6.3} req/s  waste {:>8.2} GB·s (P {:.1} / R {:.1} / S {:.1})",
            self.policy,
            self.completed,
            self.total,
            self.normalized_latency_ms(),
            self.median_ttft_ms(),
            self.throughput_rps(),
            self.waste.total(),
            self.waste.preserve_gbs,
            self.waste.recompute_gbs,
            self.waste.stall_gbs,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rec(arrival: Micros, first: Micros, fin: Micros, paused: Micros, out: usize) -> RequestRecord {
        RequestRecord {
            req: 0,
            arrival,
            first_token_at: Some(first),
            finished_at: Some(fin),
            intercepted_us: paused,
            output_tokens: out,
            interceptions: 1,
        }
    }

    #[test]
    fn normalized_latency_subtracts_interception_time() {
        let r = rec(0, 50_000, 1_050_000, 1_000_000, 10);
        // (1.05s - 1.0s paused) / 10 tokens = 5 ms/token
        assert!((r.normalized_latency_ms().unwrap() - 5.0).abs() < 1e-9);
    }

    #[test]
    fn ttft_from_arrival() {
        let r = rec(100_000, 150_000, 1_000_000, 0, 5);
        assert!((r.ttft_ms().unwrap() - 50.0).abs() < 1e-9);
    }

    #[test]
    fn unfinished_requests_have_no_latency() {
        let mut r = rec(0, 10, 20, 0, 5);
        r.finished_at = None;
        assert!(r.normalized_latency_ms().is_none());
    }

    #[test]
    fn recorder_attributes_recompute_time() {
        let mut m = Recorder::default();
        // iteration: 100 ms, of which 90 ms attributed to recompute
        m.iteration(100_000, 0, 10, 90, 90, 90_000.0);
        // iteration: 100 ms, pure decode
        m.iteration(100_000, 0, 100, 0, 0, 0.0);
        let f = m.recompute_fwd_fraction();
        assert!((f - 0.45).abs() < 1e-9, "{f}");
    }

    #[test]
    fn report_aggregates() {
        let mut m = Recorder::default();
        m.run_started = 0;
        m.run_ended = 2_000_000;
        m.finish_request(rec(0, 100_000, 1_000_000, 0, 100));
        m.finish_request(rec(0, 200_000, 2_000_000, 1_000_000, 100));
        let rep = m.report("test", "lbl");
        assert_eq!(rep.completed, 2);
        assert!((rep.throughput_rps() - 1.0).abs() < 1e-9);
        // latencies: 10 ms/tok and 10 ms/tok -> median 10
        assert!((rep.normalized_latency_ms() - 10.0).abs() < 1e-9);
    }
}
