//! Machine-readable report writer. Hand-rolled JSON (the crate is
//! dependency-free); output is fully deterministic — sorted violations,
//! sorted `by_rule` keys, and deliberately no timestamp (detlint polices
//! wall-clock use and takes its own medicine).

use std::collections::BTreeMap;

use crate::rules::Violation;

pub const REPORT_VERSION: u64 = 1;

fn esc(s: &str) -> String {
    let mut out = String::with_capacity(s.len() + 2);
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

/// Render the full report. `rules` is the enabled rule set (full ids).
pub fn render_json(root: &str, files_scanned: usize, rules: &[String], vs: &[Violation]) -> String {
    let waived = vs.iter().filter(|v| v.waived).count();
    let mut by_rule: BTreeMap<&str, usize> = BTreeMap::new();
    for v in vs {
        *by_rule.entry(v.rule.as_str()).or_insert(0) += 1;
    }
    let mut out = String::new();
    out.push_str("{\n");
    out.push_str(&format!("  \"version\": {REPORT_VERSION},\n"));
    out.push_str(&format!("  \"root\": \"{}\",\n", esc(root)));
    out.push_str(&format!("  \"files_scanned\": {files_scanned},\n"));
    let rule_list: Vec<String> = rules.iter().map(|r| format!("\"{}\"", esc(r))).collect();
    out.push_str(&format!("  \"rules\": [{}],\n", rule_list.join(", ")));
    out.push_str("  \"violations\": [");
    for (i, v) in vs.iter().enumerate() {
        if i > 0 {
            out.push(',');
        }
        out.push_str("\n    {");
        out.push_str(&format!("\"rule\": \"{}\", ", esc(&v.rule)));
        out.push_str(&format!("\"file\": \"{}\", ", esc(&v.file)));
        out.push_str(&format!("\"line\": {}, ", v.line));
        out.push_str(&format!("\"message\": \"{}\", ", esc(&v.message)));
        out.push_str(&format!("\"waived\": {}", v.waived));
        if let Some(j) = &v.justification {
            out.push_str(&format!(", \"justification\": \"{}\"", esc(j)));
        }
        out.push('}');
    }
    if vs.is_empty() {
        out.push_str("],\n");
    } else {
        out.push_str("\n  ],\n");
    }
    out.push_str("  \"summary\": {\n");
    out.push_str(&format!("    \"total\": {},\n", vs.len()));
    out.push_str(&format!("    \"waived\": {waived},\n"));
    out.push_str(&format!("    \"unwaived\": {},\n", vs.len() - waived));
    out.push_str("    \"by_rule\": {");
    let rule_counts: Vec<String> =
        by_rule.iter().map(|(r, c)| format!("\"{}\": {c}", esc(r))).collect();
    out.push_str(&rule_counts.join(", "));
    out.push_str("}\n");
    out.push_str("  }\n");
    out.push_str("}\n");
    out
}
