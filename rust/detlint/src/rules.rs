//! The five determinism & invariant rules, plus waiver handling and the
//! directory scan driver. See docs/determinism.md for the contracts.

use std::collections::{BTreeMap, BTreeSet};
use std::path::Path;

use crate::lexer::{
    find_from, find_idents, in_regions, is_ident_char, is_ident_start, match_brace, next_nonspace,
    prev_token, rfind_any, test_regions, Masked,
};

pub const R1: &str = "r1-no-wall-clock";
pub const R2: &str = "r2-no-hash-order";
pub const R3: &str = "r3-journal-completeness";
pub const R4: &str = "r4-no-panic-surface";
pub const R5: &str = "r5-seeded-rng-only";
/// Synthetic rule for malformed or stale waivers (never waivable itself).
pub const WAIVER_SYNTAX: &str = "waiver-syntax";

/// All real rules, in report order.
pub const ALL_RULES: [&str; 5] = [R1, R2, R3, R4, R5];

/// Modules whose behavior feeds scheduling decisions: wall clock, hash
/// order, and unseeded entropy are forbidden here.
const DECISION_PREFIXES: [&str; 6] =
    ["engine", "coordinator", "kvcache", "faults", "speculation", "serving"];

/// Files forming the client-facing serving surface: must never panic.
const R4_FILES: [&str; 2] = ["serving/front.rs", "serving/events.rs"];

/// Identifiers that reach for the wall clock or OS entropy (r1).
const R1_IDENTS: [&str; 5] = ["Instant", "SystemTime", "sleep", "gettimeofday", "getrandom"];

/// Identifiers that construct unseeded randomness (r5).
const R5_IDENTS: [&str; 6] =
    ["thread_rng", "from_entropy", "OsRng", "from_os_rng", "getrandom", "EntropyRng"];

/// Methods whose call on a hash-ordered container observes its order (r2).
const ITER_METHODS: [&str; 10] = [
    "iter",
    "iter_mut",
    "keys",
    "values",
    "values_mut",
    "drain",
    "into_iter",
    "into_keys",
    "into_values",
    "retain",
];

/// Type wrappers walked through when resolving a declared container name.
const WRAPPERS: [&str; 8] = ["Mutex", "RwLock", "Arc", "Rc", "Box", "RefCell", "Cell", "Option"];

/// Types whose `&mut self` methods must journal into the dirty set (r3).
const R3_TARGETS: [&str; 3] = ["ReqTable", "CacheManager", "FcfsQueue"];

/// Macros that unconditionally panic (r4). `assert!`/`debug_assert!` are
/// deliberately NOT listed: they state invariants, not control flow.
const PANIC_MACROS: [&str; 4] = ["panic", "unreachable", "todo", "unimplemented"];

/// Body substrings that count as journaling for each r3 target.
fn r3_markers(ty: &str) -> &'static [&'static str] {
    match ty {
        "FcfsQueue" => &["self.record(", "journal"],
        _ => &["dirty.mark(", "dirty.drain_into(", "dirty.compact_below("],
    }
}

/// Resolve a waiver rule name (`r2` or `r2-no-hash-order`) to its full id.
pub fn full_rule(name: &str) -> Option<&'static str> {
    match name {
        "r1" | R1 => Some(R1),
        "r2" | R2 => Some(R2),
        "r3" | R3 => Some(R3),
        "r4" | R4 => Some(R4),
        "r5" | R5 => Some(R5),
        _ => None,
    }
}

/// One diagnostic. `line` is 1-based; `file` is the path relative to the
/// scanned root, with `/` separators.
#[derive(Clone, Debug)]
pub struct Violation {
    pub rule: String,
    pub file: String,
    pub line: usize,
    pub message: String,
    pub waived: bool,
    pub justification: Option<String>,
}

/// An inline `// detlint: allow(<rules>) — <justification>` directive.
struct Waiver {
    rules: Vec<&'static str>,
    justification: String,
    line: usize,
    /// Lines this waiver covers: its own line (trailing form) or the next
    /// code line, extended through `#[…]` attributes to the decorated item.
    targets: BTreeSet<usize>,
    used: bool,
}

fn parse_waivers(m: &Masked) -> (Vec<Waiver>, Vec<(usize, String)>) {
    let mut waivers = Vec::new();
    let mut bad: Vec<(usize, String)> = Vec::new();
    for (start, ctext) in &m.comments {
        let Some(pos) = ctext.find("detlint:") else { continue };
        let line = m.line_of(*start);
        let rest = ctext[pos + "detlint:".len()..].trim();
        let Some(list) = rest.strip_prefix("allow(") else {
            bad.push((
                line,
                "unrecognized detlint directive (expected \
                 `detlint: allow(<rules>) — <justification>`)"
                    .to_string(),
            ));
            continue;
        };
        let Some(close) = list.find(')') else {
            bad.push((line, "unterminated rule list in detlint waiver".to_string()));
            continue;
        };
        let mut rules = Vec::new();
        let mut ok = true;
        for r in list[..close].split(',') {
            let r = r.trim();
            match full_rule(r) {
                Some(full) => rules.push(full),
                None => {
                    bad.push((line, format!("unknown rule `{r}` in detlint waiver")));
                    ok = false;
                }
            }
        }
        let just = list[close + 1..]
            .trim()
            .trim_start_matches(|c: char| matches!(c, '\u{2014}' | '\u{2013}' | '-' | ':'))
            .trim()
            .to_string();
        if just.is_empty() {
            bad.push((line, "detlint waiver missing a justification".to_string()));
            ok = false;
        }
        if !ok || rules.is_empty() {
            continue;
        }
        let (s, _) = m.line_span(line);
        let trailing = m.code[s..*start].iter().any(|c| !c.is_whitespace());
        let mut targets = BTreeSet::new();
        if trailing {
            targets.insert(line);
        } else {
            let mut nxt = line + 1;
            while nxt <= m.num_lines() && !m.line_has_code(nxt) {
                nxt += 1;
            }
            if nxt <= m.num_lines() {
                targets.insert(nxt);
                while nxt <= m.num_lines() && m.code_line(nxt).trim().starts_with("#[") {
                    nxt += 1;
                    while nxt <= m.num_lines() && !m.line_has_code(nxt) {
                        nxt += 1;
                    }
                    if nxt <= m.num_lines() {
                        targets.insert(nxt);
                    }
                }
            }
        }
        waivers.push(Waiver { rules, justification: just, line, targets, used: false });
    }
    (waivers, bad)
}

/// One scanned file plus everything the rules derived from it.
pub struct FileScan {
    pub rel: String,
    pub m: Masked,
    waivers: Vec<Waiver>,
    bad_waivers: Vec<(usize, String)>,
    tests: Vec<(usize, usize)>,
    pub violations: Vec<Violation>,
}

impl FileScan {
    pub fn new(rel: String, src: &str) -> FileScan {
        let m = Masked::new(src);
        let (waivers, bad_waivers) = parse_waivers(&m);
        let tests = test_regions(&m);
        FileScan { rel, m, waivers, bad_waivers, tests, violations: Vec::new() }
    }

    fn decision_path(&self) -> bool {
        DECISION_PREFIXES
            .iter()
            .any(|p| self.rel == *p || self.rel.starts_with(&format!("{p}/")))
    }

    fn waived(&mut self, rule: &str, line: usize) -> Option<String> {
        for w in &mut self.waivers {
            if w.rules.iter().any(|r| *r == rule) && w.targets.contains(&line) {
                w.used = true;
                return Some(w.justification.clone());
            }
        }
        None
    }

    fn report_at(&mut self, rule: &str, offset: usize, message: String) {
        let line = self.m.line_of(offset);
        self.report_line(rule, line, message);
    }

    fn report_line(&mut self, rule: &str, line: usize, message: String) {
        let just = self.waived(rule, line);
        self.violations.push(Violation {
            rule: rule.to_string(),
            file: self.rel.clone(),
            line,
            message,
            waived: just.is_some(),
            justification: just,
        });
    }
}

/// r1 / r5: flag each forbidden identifier outside test regions.
fn scan_idents_rule(fs: &mut FileScan, rule: &'static str, idents: &[&str], what: &str) {
    for name in idents {
        let hits = find_idents(&fs.m.code, name);
        for p in hits {
            if in_regions(&fs.tests, p) {
                continue;
            }
            let msg = format!("{what}: `{name}` is forbidden in decision-path modules");
            fs.report_at(rule, p, msg);
        }
    }
}

/// Declared hash-container bindings: `(decl_offset, type_name, binding)`.
/// The binding is resolved by walking back from the type through wrappers,
/// references, generics and path segments to `name :` or `name =`.
fn collect_hash_names(fs: &FileScan) -> (BTreeSet<String>, Vec<(usize, String, Option<String>)>) {
    let mut names = BTreeSet::new();
    let mut decl_sites = Vec::new();
    let code = &fs.m.code;
    for tyname in ["HashMap", "HashSet"] {
        for p in find_idents(code, tyname) {
            if in_regions(&fs.tests, p) {
                continue;
            }
            // `use std::collections::{…}` introduces no binding; skip it —
            // actual declarations are flagged at their own sites.
            if fs.m.code_line(fs.m.line_of(p)).trim_start().starts_with("use ") {
                continue;
            }
            let mut pos = p;
            loop {
                let (t, tstart) = prev_token(code, pos);
                if t == "<"
                    || t == "&"
                    || t == "::"
                    || t == "mut"
                    || WRAPPERS.iter().any(|w| *w == t)
                {
                    pos = tstart;
                    continue;
                }
                if !t.is_empty() && is_ident_start(t.chars().next().unwrap_or(' ')) {
                    // A bare path segment: keep walking only through `::`.
                    let (t2, t2start) = prev_token(code, tstart);
                    if t2 == "::" {
                        pos = t2start;
                        continue;
                    }
                }
                break;
            }
            let mut name = None;
            let (t, tstart) = prev_token(code, pos);
            if t == ":" {
                let (n2, _) = prev_token(code, tstart);
                if !n2.is_empty() && is_ident_char(n2.chars().next().unwrap_or(' ')) {
                    name = Some(n2);
                }
            } else if t == "=" {
                let (mut n2, n2s) = prev_token(code, tstart);
                if n2 == "mut" {
                    n2 = prev_token(code, n2s).0;
                }
                if !n2.is_empty() && is_ident_char(n2.chars().next().unwrap_or(' ')) {
                    name = Some(n2);
                }
            }
            if let Some(n) = &name {
                names.insert(n.clone());
            }
            decl_sites.push((p, tyname.to_string(), name));
        }
    }
    (names, decl_sites)
}

/// r2: hash-ordered containers (declarations, iteration calls, `for` loops)
/// in decision-path modules.
fn rule_r2(fs: &mut FileScan) {
    let (names, decl_sites) = collect_hash_names(fs);
    for (p, tyname, name) in decl_sites {
        let nm = match &name {
            Some(n) => format!(" `{n}`"),
            None => String::new(),
        };
        fs.report_at(
            R2,
            p,
            format!(
                "hash-ordered container{nm} ({tyname}) in a decision-path module: \
                 iteration order would leak into plans — use BTreeMap/BTreeSet \
                 or waive with a point-lookup justification"
            ),
        );
    }
    let code = fs.m.code.clone();
    for meth in ITER_METHODS {
        for p in find_idents(&code, meth) {
            if in_regions(&fs.tests, p) {
                continue;
            }
            if p == 0 || code[p - 1] != '.' {
                continue;
            }
            let e = next_nonspace(&code, p + meth.chars().count());
            if e >= code.len() || code[e] != '(' {
                continue;
            }
            let (recv, _) = prev_token(&code, p - 1);
            if names.contains(&recv) {
                fs.report_at(
                    R2,
                    p,
                    format!(
                        "iteration over hash-ordered `{recv}` (`.{meth}()`): \
                         non-deterministic order in a decision path"
                    ),
                );
            }
        }
    }
    for p in find_idents(&code, "for") {
        if in_regions(&fs.tests, p) {
            continue;
        }
        let Some(brace) = find_from(&code, "{", p) else { continue };
        let seg = &code[p..brace];
        let Some(ipos) = find_idents(seg, "in").first().copied() else { continue };
        let expr: String = seg[ipos + 2..].iter().collect();
        let expr = expr.trim();
        if expr.contains('(') {
            continue; // call chains are handled by the method scan above
        }
        let expr = expr.trim_start_matches('&').trim();
        let expr = expr.strip_prefix("mut ").unwrap_or(expr).trim();
        let last = expr.rsplit('.').next().unwrap_or("").trim();
        if names.contains(last) {
            fs.report_at(
                R2,
                p,
                format!(
                    "`for … in` over hash-ordered `{last}`: \
                     non-deterministic order in a decision path"
                ),
            );
        }
    }
}

/// r4: panics on the serving surface — `.unwrap()`, `.expect(…)`, panicking
/// macros, and non-literal indexing.
fn rule_r4(fs: &mut FileScan) {
    let code = fs.m.code.clone();
    for p in find_idents(&code, "unwrap") {
        if in_regions(&fs.tests, p) || p == 0 || code[p - 1] != '.' {
            continue;
        }
        let e = next_nonspace(&code, p + "unwrap".chars().count());
        if e < code.len() && code[e] == '(' {
            let inner = next_nonspace(&code, e + 1);
            if inner < code.len() && code[inner] == ')' {
                fs.report_at(
                    R4,
                    p,
                    "`.unwrap()` on the serving surface: return a typed error or recover \
                     (poisoned locks: `unwrap_or_else(PoisonError::into_inner)`)"
                        .to_string(),
                );
            }
        }
    }
    for p in find_idents(&code, "expect") {
        if in_regions(&fs.tests, p) || p == 0 || code[p - 1] != '.' {
            continue;
        }
        let e = next_nonspace(&code, p + "expect".chars().count());
        if e < code.len() && code[e] == '(' {
            fs.report_at(
                R4,
                p,
                "`.expect()` on the serving surface: return a typed error or waive \
                 with the invariant that makes it unreachable"
                    .to_string(),
            );
        }
    }
    for mac in PANIC_MACROS {
        for p in find_idents(&code, mac) {
            if in_regions(&fs.tests, p) {
                continue;
            }
            let e = next_nonspace(&code, p + mac.chars().count());
            if e < code.len() && code[e] == '!' {
                fs.report_at(
                    R4,
                    p,
                    format!("`{mac}!` on the serving surface: never panic on client-facing paths"),
                );
            }
        }
    }
    for p in 0..code.len() {
        if code[p] != '[' || in_regions(&fs.tests, p) || p == 0 {
            continue;
        }
        // Indexing only: the `[` must follow an expression (identifier or a
        // closing `)`/`]`), which excludes slice types, attributes (`#[`)
        // and macro brackets (`vec![`).
        let mut j = p - 1;
        while code[j].is_whitespace() {
            if j == 0 {
                break;
            }
            j -= 1;
        }
        if !(is_ident_char(code[j]) || code[j] == ')' || code[j] == ']') {
            continue;
        }
        let mut depth = 0i64;
        let mut e = p;
        while e < code.len() {
            if code[e] == '[' {
                depth += 1;
            } else if code[e] == ']' {
                depth -= 1;
                if depth == 0 {
                    break;
                }
            }
            e += 1;
        }
        let inner: String = code[p + 1..e.min(code.len())].iter().collect();
        let inner = inner.trim().to_string();
        if inner.is_empty() || inner.chars().all(|c| c.is_ascii_digit()) {
            continue;
        }
        fs.report_at(
            R4,
            p,
            format!(
                "non-literal indexing `[{inner}]` on the serving surface can panic: \
                 use `.get()` or waive with the bounds invariant"
            ),
        );
    }
}

struct MethodInfo {
    file_idx: usize,
    line: usize,
    is_pub: bool,
    mut_self: bool,
    body: Vec<char>,
    calls: BTreeSet<String>,
}

/// r3: every `pub` `&mut self` method on a journal-bearing type must reach a
/// journal mark, directly or through another compliant method (fixpoint over
/// `self.…(…)` calls).
fn rule_r3(files: &mut [FileScan]) {
    let mut methods: BTreeMap<&'static str, BTreeMap<String, MethodInfo>> =
        R3_TARGETS.iter().map(|t| (*t, BTreeMap::new())).collect();

    for (file_idx, fs) in files.iter().enumerate() {
        let code = &fs.m.code;
        for p in find_idents(code, "impl") {
            if in_regions(&fs.tests, p) {
                continue;
            }
            let Some(brace) = find_from(code, "{", p) else { continue };
            let head: String = code[p + 4..brace].iter().collect();
            let head_norm = format!(" {} ", head.split_whitespace().collect::<Vec<_>>().join(" "));
            if head_norm.contains(" for ") {
                continue; // trait impl — only inherent impls carry the contract
            }
            let cleaned = head.replace(['<', '>'], " ");
            let mut tyname: Option<&'static str> = None;
            for s in cleaned.split_whitespace().rev() {
                if is_ident_start(s.chars().next().unwrap_or(' ')) {
                    let last_seg = s.rsplit("::").next().unwrap_or(s);
                    tyname = R3_TARGETS.iter().find(|&&t| t == last_seg).copied();
                    break;
                }
            }
            let Some(tyname) = tyname else { continue };
            let end = match_brace(code, brace);
            let mut q = brace + 1;
            while q < end {
                let Some(fnp) = find_from(code, "fn ", q) else { break };
                if fnp >= end {
                    break;
                }
                q = fnp + 3;
                if fnp > 0 && is_ident_char(code[fnp - 1]) {
                    continue;
                }
                let mut depth = 0i64;
                for k in brace..fnp {
                    if code[k] == '{' {
                        depth += 1;
                    } else if code[k] == '}' {
                        depth -= 1;
                    }
                }
                if depth != 1 {
                    continue; // nested fn (closure body, inner item)
                }
                let back = rfind_any(code, ";{}", brace, fnp).unwrap_or(brace);
                let vis_seg = &code[back + 1..fnp];
                let is_pub = !find_idents(vis_seg, "pub").is_empty();
                let nm_start = next_nonspace(code, fnp + 2);
                let mut nm_end = nm_start;
                while nm_end < code.len() && is_ident_char(code[nm_end]) {
                    nm_end += 1;
                }
                let name: String = code[nm_start..nm_end].iter().collect();
                let Some(par_open) = find_from(code, "(", nm_end) else { continue };
                if par_open >= end {
                    continue;
                }
                let mut pdepth = 0i64;
                let mut par_close = par_open;
                while par_close < end {
                    if code[par_close] == '(' {
                        pdepth += 1;
                    } else if code[par_close] == ')' {
                        pdepth -= 1;
                        if pdepth == 0 {
                            break;
                        }
                    }
                    par_close += 1;
                }
                let par_hi = par_close.min(code.len() - 1);
                let params: String = code[par_open..=par_hi].iter().collect();
                let spaced = params
                    .replace('&', " & ")
                    .replace(',', " , ")
                    .replace('(', " ( ")
                    .replace(')', " ) ");
                let toks: Vec<&str> = spaced.split_whitespace().collect();
                let mut mut_self = false;
                for idx in 0..toks.len() {
                    if toks[idx] == "&" {
                        let mut k = idx + 1;
                        if k < toks.len() && toks[k].starts_with('\'') {
                            k += 1;
                        }
                        if k + 1 < toks.len() && toks[k] == "mut" && toks[k + 1] == "self" {
                            mut_self = true;
                            break;
                        }
                    }
                }
                let mut bodyp = par_close;
                let mut body: Vec<char> = Vec::new();
                let mut body_end = par_close;
                while bodyp < end && code[bodyp] != '{' && code[bodyp] != ';' {
                    bodyp += 1;
                }
                if bodyp < end && code[bodyp] == '{' {
                    body_end = match_brace(code, bodyp);
                    body = code[bodyp..body_end].to_vec();
                }
                let mut calls = BTreeSet::new();
                let mut bi = 0;
                while let Some(sp) = find_from(&body, "self.", bi) {
                    bi = sp + 5;
                    let mut ce = bi;
                    while ce < body.len() && is_ident_char(body[ce]) {
                        ce += 1;
                    }
                    let np = next_nonspace(&body, ce);
                    if np < body.len() && body[np] == '(' {
                        calls.insert(body[bi..ce].iter().collect::<String>());
                    }
                }
                let info = MethodInfo {
                    file_idx,
                    line: fs.m.line_of(fnp),
                    is_pub,
                    mut_self,
                    body: body.clone(),
                    calls,
                };
                if let Some(per_ty) = methods.get_mut(tyname) {
                    per_ty.insert(name, info);
                }
                q = if body.is_empty() { par_close + 1 } else { body_end };
            }
        }
    }

    for (tyname, ms) in &methods {
        let markers = r3_markers(tyname);
        let mut compliant: BTreeSet<String> = ms
            .iter()
            .filter(|(_, info)| markers.iter().any(|mk| find_from(&info.body, mk, 0).is_some()))
            .map(|(name, _)| name.clone())
            .collect();
        let mut changed = true;
        while changed {
            changed = false;
            for (name, info) in ms {
                if compliant.contains(name) {
                    continue;
                }
                if info.calls.iter().any(|c| compliant.contains(c)) {
                    compliant.insert(name.clone());
                    changed = true;
                }
            }
        }
        for (name, info) in ms {
            if !(info.is_pub && info.mut_self) || compliant.contains(name) {
                continue;
            }
            files[info.file_idx].report_line(
                R3,
                info.line,
                format!(
                    "`{tyname}::{name}` takes `&mut self` but never journals into the \
                     dirty set — O(batch) delta capture silently misses its mutations \
                     (call the journal mark or waive with why no tracked state changes)"
                ),
            );
        }
    }
}

/// Recursively collect `.rs` files under `dir`, as sorted root-relative paths.
fn collect_rs_files(root: &Path, dir: &Path, out: &mut Vec<String>) -> std::io::Result<()> {
    let mut entries: Vec<std::fs::DirEntry> =
        std::fs::read_dir(dir)?.collect::<Result<_, _>>()?;
    entries.sort_by_key(|e| e.file_name());
    for entry in entries {
        let path = entry.path();
        if path.is_dir() {
            collect_rs_files(root, &path, out)?;
        } else if path.extension().is_some_and(|e| e == "rs") {
            let rel = path.strip_prefix(root).unwrap_or(&path);
            out.push(rel.to_string_lossy().replace('\\', "/"));
        }
    }
    Ok(())
}

/// Scan every `.rs` file under `root` with the `enabled` rules (full ids).
/// Returns `(violations sorted by (file, line, rule), files_scanned)`.
pub fn scan_tree(
    root: &Path,
    enabled: &BTreeSet<String>,
) -> std::io::Result<(Vec<Violation>, usize)> {
    let mut rels = Vec::new();
    collect_rs_files(root, root, &mut rels)?;
    rels.sort();
    let mut files: Vec<FileScan> = Vec::new();
    for rel in &rels {
        let src = std::fs::read_to_string(root.join(rel))?;
        files.push(FileScan::new(rel.clone(), &src));
    }
    for fs in &mut files {
        for (line, msg) in std::mem::take(&mut fs.bad_waivers) {
            fs.violations.push(Violation {
                rule: WAIVER_SYNTAX.to_string(),
                file: fs.rel.clone(),
                line,
                message: msg,
                waived: false,
                justification: None,
            });
        }
        if fs.decision_path() {
            if enabled.contains(R1) {
                scan_idents_rule(fs, R1, &R1_IDENTS, "wall clock / OS timing");
            }
            if enabled.contains(R2) {
                rule_r2(fs);
            }
            if enabled.contains(R5) {
                scan_idents_rule(fs, R5, &R5_IDENTS, "unseeded entropy source");
            }
        }
        if enabled.contains(R4) && R4_FILES.iter().any(|f| *f == fs.rel) {
            rule_r4(fs);
        }
    }
    if enabled.contains(R3) {
        rule_r3(&mut files);
    }
    // An unused waiver is itself a violation: its justification is stale and
    // would silently mask a future regression at that site.
    for fs in &mut files {
        for i in 0..fs.waivers.len() {
            if !fs.waivers[i].used {
                let line = fs.waivers[i].line;
                fs.violations.push(Violation {
                    rule: WAIVER_SYNTAX.to_string(),
                    file: fs.rel.clone(),
                    line,
                    message: "waiver matches no violation (stale?)".to_string(),
                    waived: false,
                    justification: None,
                });
            }
        }
    }
    let mut out: Vec<Violation> = files.into_iter().flat_map(|fs| fs.violations).collect();
    out.sort_by(|a, b| {
        (a.file.as_str(), a.line, a.rule.as_str()).cmp(&(b.file.as_str(), b.line, b.rule.as_str()))
    });
    Ok((out, rels.len()))
}
