//! detlint — determinism & invariant static analysis for the scheduling core.
//!
//! The InferCept coordinator promises bit-identical schedules for identical
//! inputs (the determinism, policy-parity, capture-delta and chaos suites all
//! pin on it). That promise is easy to break silently: one `Instant::now()`
//! in an admission path, one `HashMap` iteration feeding a plan, one
//! `&mut self` mutation that skips the dirty-set journal. detlint makes the
//! five contracts machine-checked:
//!
//! - **r1-no-wall-clock** — no wall clock / OS timing in decision paths
//!   (`engine/`, `coordinator/`, `kvcache/`, `faults/`, `speculation/`,
//!   `serving/`); the virtual clock is the only time source there.
//! - **r2-no-hash-order** — no hash-ordered containers in decision paths;
//!   iteration order must be run-independent (point lookups can be waived).
//! - **r3-journal-completeness** — every `pub` `&mut self` method on
//!   `ReqTable` / `CacheManager` / `FcfsQueue` reaches the dirty-set /
//!   journal mark, directly or via another compliant method.
//! - **r4-no-panic-surface** — no `unwrap`/`expect`/panicking macros or
//!   unchecked indexing on the client-facing serving surface
//!   (`serving/front.rs`, `serving/events.rs`).
//! - **r5-seeded-rng-only** — randomness in decision paths derives from the
//!   config seed, never from thread/OS entropy.
//!
//! Findings are suppressed inline with
//! `// detlint: allow(<rules>) — <justification>`; a waiver without a
//! justification, naming an unknown rule, or matching no violation is itself
//! an error. The analysis is intentionally lexical (no rustc, no syn): it
//! masks comments/strings, skips `#[cfg(test)]` regions, and scans with
//! ident-boundary precision. That keeps it dependency-free and offline, at
//! the cost of being a lint, not a proof — see docs/determinism.md.

pub mod lexer;
pub mod report;
pub mod rules;

use std::collections::BTreeSet;
use std::path::Path;

pub use rules::{full_rule, scan_tree, Violation, ALL_RULES};

/// Scan with every rule enabled.
pub fn scan_all(root: &Path) -> std::io::Result<(Vec<Violation>, usize)> {
    let enabled: BTreeSet<String> = ALL_RULES.iter().map(|r| r.to_string()).collect();
    scan_tree(root, &enabled)
}

#[cfg(test)]
mod tests {
    use super::lexer::{find_idents, Masked};

    #[test]
    fn masks_comments_and_strings() {
        let m = Masked::new("let x = \"Instant\"; // Instant\nlet y = Instant::now();\n");
        assert_eq!(find_idents(&m.code, "Instant").len(), 1);
        assert_eq!(m.comments.len(), 1);
    }

    #[test]
    fn masks_raw_strings_and_chars() {
        let m = Masked::new("let s = r#\"HashMap \"quoted\" body\"#; let c = 'H'; let l: &'a u8;");
        assert!(find_idents(&m.code, "HashMap").is_empty());
        assert_eq!(find_idents(&m.code, "l").len(), 1);
    }

    #[test]
    fn nested_block_comments() {
        let m = Masked::new("/* outer /* inner */ still comment */ let sleep = 1;");
        assert_eq!(find_idents(&m.code, "sleep").len(), 1);
    }
}
