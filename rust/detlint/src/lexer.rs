//! A masking lexer: everything the rules must not see (comments, string and
//! char literal bodies) is blanked out with spaces, preserving offsets and
//! line structure, so the rule scans can use plain substring searches over
//! `code` without false positives from prose.
//!
//! The unit of position throughout detlint is a *char index* into the file
//! (not a byte offset): `text` and `code` are `Vec<char>` and all helpers
//! take/return indices into them.

/// `true` for characters that can appear inside a Rust identifier.
pub fn is_ident_char(c: char) -> bool {
    c.is_alphanumeric() || c == '_'
}

/// `true` for characters that can *start* an identifier.
pub fn is_ident_start(c: char) -> bool {
    is_ident_char(c) && !c.is_ascii_digit()
}

/// Find `pat` in `code[start..]`, returning the char index of the match.
pub fn find_from(code: &[char], pat: &str, start: usize) -> Option<usize> {
    let p: Vec<char> = pat.chars().collect();
    if p.is_empty() {
        return Some(start.min(code.len()));
    }
    if start >= code.len() || code.len() - start < p.len() {
        return None;
    }
    let last = code.len() - p.len();
    for i in start..=last {
        if code[i..i + p.len()] == p[..] {
            return Some(i);
        }
    }
    None
}

/// Find the last occurrence of any char in `set` within `code[start..end)`.
pub fn rfind_any(code: &[char], set: &str, start: usize, end: usize) -> Option<usize> {
    let end = end.min(code.len());
    for i in (start..end).rev() {
        if set.contains(code[i]) {
            return Some(i);
        }
    }
    None
}

/// All ident-boundary-delimited occurrences of `name` in `code`.
pub fn find_idents(code: &[char], name: &str) -> Vec<usize> {
    let mut out = Vec::new();
    let len = name.chars().count();
    let mut start = 0;
    while let Some(p) = find_from(code, name, start) {
        start = p + 1;
        if p > 0 && is_ident_char(code[p - 1]) {
            continue;
        }
        let e = p + len;
        if e < code.len() && is_ident_char(code[e]) {
            continue;
        }
        out.push(p);
    }
    out
}

/// The token ending strictly before `pos`: `(text, start_index)`.
/// Returns an empty string at beginning-of-file. Identifier runs come back
/// whole; `::` comes back as one token; anything else is a single char.
pub fn prev_token(code: &[char], pos: usize) -> (String, usize) {
    if pos == 0 {
        return (String::new(), 0);
    }
    let mut j = pos - 1;
    while code[j].is_whitespace() {
        if j == 0 {
            return (String::new(), 0);
        }
        j -= 1;
    }
    if is_ident_char(code[j]) {
        let e = j + 1;
        let mut s = j;
        while s > 0 && is_ident_char(code[s - 1]) {
            s -= 1;
        }
        return (code[s..e].iter().collect(), s);
    }
    if code[j] == ':' && j > 0 && code[j - 1] == ':' {
        return ("::".to_string(), j - 1);
    }
    (code[j].to_string(), j)
}

/// First non-whitespace char index at or after `pos`.
pub fn next_nonspace(code: &[char], pos: usize) -> usize {
    let mut j = pos;
    while j < code.len() && code[j].is_whitespace() {
        j += 1;
    }
    j
}

/// Index just past the brace matching `code[open_idx] == '{'`.
pub fn match_brace(code: &[char], open_idx: usize) -> usize {
    let mut depth = 0i64;
    for (j, &c) in code.iter().enumerate().skip(open_idx) {
        if c == '{' {
            depth += 1;
        } else if c == '}' {
            depth -= 1;
            if depth == 0 {
                return j + 1;
            }
        }
    }
    code.len()
}

/// A source file with comments and literal bodies masked out.
pub struct Masked {
    /// Original source, as chars.
    pub text: Vec<char>,
    /// Source with comments and string/char bodies blanked to spaces
    /// (newlines preserved, so offsets and lines line up with `text`).
    pub code: Vec<char>,
    /// Every comment: `(start_char_index, comment_text)`.
    pub comments: Vec<(usize, String)>,
    /// Char index of the start of each line (line 1 starts the list).
    pub line_starts: Vec<usize>,
}

impl Masked {
    pub fn new(src: &str) -> Masked {
        let text: Vec<char> = src.chars().collect();
        let n = text.len();
        let mut code = text.clone();
        let mut comments: Vec<(usize, String)> = Vec::new();

        fn blank(out: &mut [char], s: usize, e: usize) {
            for c in out.iter_mut().take(e.min(out.len())).skip(s) {
                if *c != '\n' {
                    *c = ' ';
                }
            }
        }

        let mut i = 0;
        while i < n {
            let c = text[i];
            if c == '/' && i + 1 < n && text[i + 1] == '/' {
                let mut j = i;
                while j < n && text[j] != '\n' {
                    j += 1;
                }
                comments.push((i, text[i..j].iter().collect()));
                blank(&mut code, i, j);
                i = j;
            } else if c == '/' && i + 1 < n && text[i + 1] == '*' {
                // Block comments nest in Rust.
                let mut depth = 1;
                let mut j = i + 2;
                while j < n && depth > 0 {
                    if text[j] == '/' && j + 1 < n && text[j + 1] == '*' {
                        depth += 1;
                        j += 2;
                    } else if text[j] == '*' && j + 1 < n && text[j + 1] == '/' {
                        depth -= 1;
                        j += 2;
                    } else {
                        j += 1;
                    }
                }
                blank(&mut code, i, j);
                i = j;
            } else if c == '"' {
                let mut j = i + 1;
                while j < n {
                    if text[j] == '\\' {
                        j += 2;
                    } else if text[j] == '"' {
                        j += 1;
                        break;
                    } else {
                        j += 1;
                    }
                }
                blank(&mut code, i + 1, (i + 1).max(j.saturating_sub(1)));
                i = j;
            } else if is_ident_start(c) {
                let mut j = i;
                while j < n && is_ident_char(text[j]) {
                    j += 1;
                }
                let ident: String = text[i..j].iter().collect();
                // Raw (byte) strings: r"…", r#"…"#, br##"…"##, …
                if (ident == "r" || ident == "br") && j < n && (text[j] == '"' || text[j] == '#') {
                    let mut k = j;
                    let mut hashes = 0;
                    while k < n && text[k] == '#' {
                        hashes += 1;
                        k += 1;
                    }
                    if k < n && text[k] == '"' {
                        let close = format!("\"{}", "#".repeat(hashes));
                        let closelen = close.chars().count();
                        let e = match find_from(&text, &close, k + 1) {
                            Some(p) => p + closelen,
                            None => n,
                        };
                        blank(&mut code, k + 1, (k + 1).max(e - closelen));
                        i = e;
                        continue;
                    }
                }
                i = j;
            } else if c == '\'' {
                // Char literal vs lifetime: `'\…'` is a char; `'x'` is a
                // char; anything else (`'a`, `'static`) is a lifetime.
                if i + 1 < n && text[i + 1] == '\\' {
                    let mut j = i + 2;
                    while j < n && text[j] != '\'' {
                        j += 1;
                    }
                    blank(&mut code, i + 1, j);
                    i = j + 1;
                } else if i + 2 < n && text[i + 2] == '\'' && text[i + 1] != '\'' {
                    blank(&mut code, i + 1, i + 2);
                    i += 3;
                } else {
                    i += 1;
                }
            } else {
                i += 1;
            }
        }

        let mut line_starts = vec![0];
        for (idx, &ch) in text.iter().enumerate() {
            if ch == '\n' {
                line_starts.push(idx + 1);
            }
        }
        Masked { text, code, comments, line_starts }
    }

    /// 1-based line number holding char index `offset`.
    pub fn line_of(&self, offset: usize) -> usize {
        self.line_starts.partition_point(|&s| s <= offset)
    }

    /// `[start, end)` char span of 1-based `line` (end excludes the newline).
    pub fn line_span(&self, line: usize) -> (usize, usize) {
        let s = self.line_starts[line - 1];
        let e = if line < self.line_starts.len() {
            self.line_starts[line] - 1
        } else {
            self.text.len()
        };
        (s, e)
    }

    /// Does 1-based `line` contain any non-masked, non-whitespace code?
    pub fn line_has_code(&self, line: usize) -> bool {
        let (s, e) = self.line_span(line);
        self.code[s..e].iter().any(|c| !c.is_whitespace())
    }

    /// Masked content of 1-based `line`, as a String.
    pub fn code_line(&self, line: usize) -> String {
        let (s, e) = self.line_span(line);
        self.code[s..e].iter().collect()
    }

    pub fn num_lines(&self) -> usize {
        self.line_starts.len()
    }
}

/// Char-index ranges covered by `#[cfg(test)]` / `#[test]` items (merged).
/// Rules skip anything inside: tests may panic, use HashMaps, and read the
/// clock freely.
pub fn test_regions(m: &Masked) -> Vec<(usize, usize)> {
    let mut regions: Vec<(usize, usize)> = Vec::new();
    let code = &m.code;
    for pat in ["#[cfg(test)]", "#[test]"] {
        let mut start = 0;
        while let Some(p) = find_from(code, pat, start) {
            start = p + pat.chars().count();
            let mut j = start;
            while j < code.len() && code[j] != '{' && code[j] != ';' {
                j += 1;
            }
            if j < code.len() && code[j] == '{' {
                regions.push((p, match_brace(code, j)));
            }
        }
    }
    regions.sort_unstable();
    let mut merged: Vec<(usize, usize)> = Vec::new();
    for (s, e) in regions {
        match merged.last_mut() {
            Some(last) if s <= last.1 => last.1 = last.1.max(e),
            _ => merged.push((s, e)),
        }
    }
    merged
}

/// Is char index `off` inside any of the (sorted, merged) `regions`?
pub fn in_regions(regions: &[(usize, usize)], off: usize) -> bool {
    regions.iter().any(|&(s, e)| s <= off && off < e)
}
