//! detlint CLI.
//!
//! ```text
//! detlint [--root <dir>] [--json <path>] [--rules r1,r2,…] [--quiet]
//! ```
//!
//! Scans every `.rs` file under `--root` (default `src`, i.e. the scheduling
//! core when run from `rust/`). Prints one `file:line: [rule] message`
//! diagnostic per finding, writes the machine-readable report to `--json`
//! if given, and exits 0 when clean, 1 when any unwaived violation remains,
//! 2 on usage or I/O errors.

use std::collections::BTreeSet;
use std::path::PathBuf;
use std::process::ExitCode;

use detlint::report::render_json;
use detlint::{full_rule, scan_tree, ALL_RULES};

struct Opts {
    root: PathBuf,
    json: Option<PathBuf>,
    rules: BTreeSet<String>,
    quiet: bool,
}

fn usage() -> &'static str {
    "usage: detlint [--root <dir>] [--json <path>] [--rules r1,r2,…] [--quiet] [--list-rules]"
}

fn parse_opts() -> Result<Option<Opts>, String> {
    let mut root = PathBuf::from("src");
    let mut json = None;
    let mut rules: BTreeSet<String> = ALL_RULES.iter().map(|r| r.to_string()).collect();
    let mut quiet = false;
    let mut args = std::env::args().skip(1);
    while let Some(arg) = args.next() {
        match arg.as_str() {
            "--root" => {
                root = PathBuf::from(args.next().ok_or("--root needs a directory")?);
            }
            "--json" => {
                json = Some(PathBuf::from(args.next().ok_or("--json needs a path")?));
            }
            "--rules" => {
                let list = args.next().ok_or("--rules needs a comma-separated list")?;
                rules = BTreeSet::new();
                for r in list.split(',') {
                    let r = r.trim();
                    let full = full_rule(r).ok_or_else(|| format!("unknown rule `{r}`"))?;
                    rules.insert(full.to_string());
                }
            }
            "--quiet" | "-q" => quiet = true,
            "--list-rules" => {
                for r in ALL_RULES {
                    println!("{r}");
                }
                return Ok(None);
            }
            "--help" | "-h" => {
                println!("{}", usage());
                return Ok(None);
            }
            other => return Err(format!("unknown argument `{other}`\n{}", usage())),
        }
    }
    Ok(Some(Opts { root, json, rules, quiet }))
}

fn main() -> ExitCode {
    let opts = match parse_opts() {
        Ok(Some(o)) => o,
        Ok(None) => return ExitCode::SUCCESS,
        Err(e) => {
            eprintln!("detlint: {e}");
            return ExitCode::from(2);
        }
    };
    if !opts.root.is_dir() {
        eprintln!("detlint: root `{}` is not a directory", opts.root.display());
        return ExitCode::from(2);
    }
    let (violations, files_scanned) = match scan_tree(&opts.root, &opts.rules) {
        Ok(r) => r,
        Err(e) => {
            eprintln!("detlint: scan failed: {e}");
            return ExitCode::from(2);
        }
    };
    let unwaived = violations.iter().filter(|v| !v.waived).count();
    if !opts.quiet {
        for v in &violations {
            let tag = if v.waived { "WAIVED " } else { "" };
            println!("{}:{}: {tag}[{}] {}", v.file, v.line, v.rule, v.message);
        }
        println!(
            "detlint: {files_scanned} files, {} violations ({unwaived} unwaived)",
            violations.len()
        );
    }
    if let Some(path) = &opts.json {
        let rule_list: Vec<String> = opts.rules.iter().cloned().collect();
        let body =
            render_json(&opts.root.display().to_string(), files_scanned, &rule_list, &violations);
        if let Err(e) = std::fs::write(path, body) {
            eprintln!("detlint: cannot write {}: {e}", path.display());
            return ExitCode::from(2);
        }
    }
    if unwaived > 0 {
        ExitCode::FAILURE
    } else {
        ExitCode::SUCCESS
    }
}
