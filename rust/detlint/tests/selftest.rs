//! detlint self-test: every rule is proven by a fixture that fires it at a
//! known line, waivers suppress exactly where placed, clean files stay
//! silent, and the real scheduling core (`rust/src`) is pinned at zero
//! unwaived violations.

use std::collections::BTreeSet;
use std::path::PathBuf;

use detlint::report::render_json;
use detlint::rules::{R1, R2, R3, R4, R5, WAIVER_SYNTAX};
use detlint::{scan_all, scan_tree, Violation};

fn fixtures_root() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("fixtures/tree")
}

fn shape(vs: &[Violation]) -> Vec<(String, String, usize, bool)> {
    vs.iter().map(|v| (v.rule.clone(), v.file.clone(), v.line, v.waived)).collect()
}

#[test]
fn fixtures_fire_exactly_where_expected() {
    let (vs, files) = scan_all(&fixtures_root()).expect("fixture scan");
    assert_eq!(files, 8, "fixture corpus drifted");
    let expected: Vec<(&str, &str, usize, bool)> = vec![
        (R2, "coordinator/bad_hash.rs", 7, false),
        (R2, "coordinator/bad_hash.rs", 12, false),
        (R2, "coordinator/bad_hash.rs", 20, true),
        (R1, "engine/bad_clock.rs", 5, false),
        (R1, "engine/bad_clock.rs", 7, false),
        (R1, "engine/bad_clock.rs", 13, true),
        (WAIVER_SYNTAX, "engine/bad_waivers.rs", 5, false),
        (WAIVER_SYNTAX, "engine/bad_waivers.rs", 10, false),
        (WAIVER_SYNTAX, "engine/bad_waivers.rs", 15, false),
        (R3, "kvcache/bad_journal.rs", 16, false),
        (R3, "kvcache/bad_journal.rs", 31, true),
        (R4, "serving/front.rs", 6, false),
        (R4, "serving/front.rs", 10, false),
        (R4, "serving/front.rs", 18, false),
        (R4, "serving/front.rs", 23, true),
        (R5, "speculation/bad_rng.rs", 5, false),
        (R5, "speculation/bad_rng.rs", 11, true),
    ];
    let expected: Vec<(String, String, usize, bool)> = expected
        .into_iter()
        .map(|(r, f, l, w)| (r.to_string(), f.to_string(), l, w))
        .collect();
    assert_eq!(shape(&vs), expected);
}

#[test]
fn clean_fixtures_stay_silent() {
    let (vs, _) = scan_all(&fixtures_root()).expect("fixture scan");
    for v in &vs {
        assert_ne!(v.file, "util/clock_ok.rs", "exempt path flagged: {v:?}");
        assert_ne!(v.file, "coordinator/clean.rs", "clean file flagged: {v:?}");
    }
}

#[test]
fn waived_violations_carry_their_justification() {
    let (vs, _) = scan_all(&fixtures_root()).expect("fixture scan");
    let waived: Vec<_> = vs.iter().filter(|v| v.waived).collect();
    assert_eq!(waived.len(), 5);
    for v in waived {
        let j = v.justification.as_deref().unwrap_or("");
        assert!(j.starts_with("fixture:"), "lost justification: {v:?}");
    }
}

#[test]
fn rule_toggles_disable_rules() {
    let only_r1: BTreeSet<String> = [R1.to_string()].into_iter().collect();
    let (vs, _) = scan_tree(&fixtures_root(), &only_r1).expect("fixture scan");
    assert!(vs.iter().any(|v| v.rule == R1));
    for v in &vs {
        assert!(
            v.rule == R1 || v.rule == WAIVER_SYNTAX,
            "disabled rule still fired: {v:?}"
        );
    }
}

#[test]
fn json_report_is_deterministic_and_well_formed() {
    let (vs, files) = scan_all(&fixtures_root()).expect("fixture scan");
    let rules: Vec<String> =
        [R1, R2, R3, R4, R5].iter().map(|r| r.to_string()).collect();
    let a = render_json("fixtures/tree", files, &rules, &vs);
    let b = render_json("fixtures/tree", files, &rules, &vs);
    assert_eq!(a, b);
    assert!(a.starts_with("{\n"));
    assert!(a.ends_with("}\n"));
    assert!(a.contains("\"version\": 1"));
    assert!(a.contains("\"files_scanned\": 8"));
    assert!(a.contains("\"total\": 17"));
    assert!(a.contains("\"waived\": 5"));
    assert!(a.contains("\"unwaived\": 12"));
    assert!(a.contains("\"by_rule\""));
    assert!(a.contains("\"justification\""));
}

/// The real scheduling core must be detlint-clean: every violation in
/// `rust/src` is either fixed or carries a justified waiver. This is the
/// same gate CI applies via `cargo run -p detlint`.
#[test]
fn scheduling_core_has_zero_unwaived_violations() {
    let src = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("../src");
    let (vs, files) = scan_all(&src).expect("src scan");
    assert!(files >= 40, "src tree shrank to {files} files — wrong root?");
    let unwaived: Vec<_> = vs.iter().filter(|v| !v.waived).collect();
    assert!(
        unwaived.is_empty(),
        "unwaived determinism violations in rust/src:\n{}",
        unwaived
            .iter()
            .map(|v| format!("  {}:{}: [{}] {}", v.file, v.line, v.rule, v.message))
            .collect::<Vec<_>>()
            .join("\n")
    );
}
