//! Fixture: a clean decision-path file, including a `#[cfg(test)]` module
//! that uses hash containers and panics freely. Must produce nothing.

pub fn double(x: u64) -> u64 {
    x * 2
}

#[cfg(test)]
mod tests {
    use std::collections::HashMap;

    #[test]
    fn hash_and_panics_ok_in_tests() {
        let mut m: HashMap<u64, u64> = HashMap::new();
        m.insert(1, super::double(1));
        for (_k, v) in &m {
            assert_eq!(*v, 2);
        }
        if m.is_empty() {
            panic!("unreachable in fixture");
        }
    }
}
