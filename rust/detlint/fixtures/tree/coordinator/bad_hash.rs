//! Fixture: r2-no-hash-order must fire on hash-container declarations,
//! iteration method calls, and `for … in` loops in `coordinator/`.

use std::collections::HashMap;

pub struct Plan {
    pub weights: HashMap<String, f64>,
}

pub fn total(p: &Plan) -> f64 {
    let mut sum = 0.0;
    for (_k, v) in &p.weights {
        sum += v;
    }
    sum
}

pub fn waived_keys(p: &Plan) -> Vec<String> {
    // detlint: allow(r2) — fixture: order is restored by the sort below
    let mut ks: Vec<String> = p.weights.keys().cloned().collect();
    ks.sort();
    ks
}
