//! Fixture: r4-no-panic-surface must fire on `.unwrap()`, `.expect(…)`,
//! panicking macros and non-literal indexing here, skip literal indexing
//! and `#[cfg(test)]` code, and honor a waiver.

pub fn pop(v: &mut Vec<u32>) -> u32 {
    v.pop().unwrap()
}

pub fn pick(v: &[u32], i: usize) -> u32 {
    v[i]
}

pub fn first(v: &[u32]) -> u32 {
    v[0]
}

pub fn boom() {
    panic!("fixture");
}

pub fn waived_head(v: &[u32]) -> u32 {
    // detlint: allow(r4) — fixture: caller guarantees non-empty by contract
    *v.first().expect("non-empty by contract")
}

#[cfg(test)]
mod tests {
    #[test]
    fn panics_are_fine_in_tests() {
        let v = vec![1u32];
        let i = 0;
        assert_eq!(v[i], *v.first().unwrap());
    }
}
