//! Fixture: r3-journal-completeness must fire on a `pub` `&mut self` method
//! of `ReqTable` that never reaches the dirty-set mark, accept direct and
//! transitive journaling, and honor a waiver.

pub struct DirtySet;

impl DirtySet {
    pub fn mark(&mut self, _id: u64) {}
}

pub struct ReqTable {
    dirty: DirtySet,
}

impl ReqTable {
    pub fn forgets(&mut self, id: u64) {
        let _ = id;
    }

    pub fn remembers(&mut self, id: u64) {
        self.dirty.mark(id);
    }

    pub fn via_remembers(&mut self, id: u64) {
        self.remembers(id);
    }

    fn private_unjournaled(&mut self) {}

    // detlint: allow(r3) — fixture: scratch state only, nothing snapshotted
    pub fn waived_scratch(&mut self) {}
}
