//! Fixture: r5-seeded-rng-only must fire on unseeded entropy sources in
//! `speculation/`, and honor a waiver.

pub fn draw() -> u64 {
    let _rng = rand::thread_rng();
    0
}

pub fn waived_draw() -> u64 {
    // detlint: allow(r5) — fixture: proves a waiver suppresses the finding
    let _rng = rand::rngs::OsRng;
    0
}
