//! Fixture: r1-no-wall-clock must fire on wall-clock reads in `engine/`,
//! and an inline waiver must suppress it. Not compiled — scanned only.

pub fn stamp_us() -> u64 {
    let t = std::time::Instant::now();
    let _ = t;
    std::thread::sleep(std::time::Duration::from_micros(1));
    0
}

pub fn waived_stamp() -> u64 {
    // detlint: allow(r1) — fixture: proves a waiver suppresses the finding
    let _t = std::time::SystemTime::now();
    0
}
