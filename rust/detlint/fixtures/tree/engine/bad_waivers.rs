//! Fixture: waiver hygiene. A stale waiver (matching no violation), an
//! unknown rule, and a missing justification must each raise
//! `waiver-syntax`; none of them suppress anything.

// detlint: allow(r1) — fixture: stale, nothing below touches the clock
pub fn pure(x: u64) -> u64 {
    x + 1
}

// detlint: allow(r9) — fixture: no such rule
pub fn also_pure(x: u64) -> u64 {
    x + 2
}

// detlint: allow(r1)
pub fn still_pure(x: u64) -> u64 {
    x + 3
}
