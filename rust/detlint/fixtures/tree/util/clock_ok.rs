//! Fixture: `util/` is outside the decision paths — the wall clock is legal
//! here (timing shells like `util/bench.rs` need it). Must produce nothing.

use std::time::Instant;

pub fn stamp() -> Instant {
    Instant::now()
}
