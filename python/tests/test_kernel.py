"""L1 kernel correctness: Pallas vs pure-jnp oracle.

Hypothesis sweeps the kernels over batch sizes, head/GQA geometry, context
lengths (including page-boundary edges), and dtypes — the CORE correctness
signal for the hot path.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import chunked_prefill_attention, paged_attention_decode
from compile.kernels import ref

jax.config.update("jax_enable_x64", False)


def _tol(dtype):
    return dict(rtol=2e-5, atol=2e-5) if dtype == jnp.float32 else dict(
        rtol=2e-2, atol=2e-2
    )


def make_pool(rng, n_blocks, block_size, kv_heads, head_dim, dtype):
    k = rng.standard_normal((n_blocks, block_size, kv_heads, head_dim))
    v = rng.standard_normal((n_blocks, block_size, kv_heads, head_dim))
    return jnp.asarray(k, dtype), jnp.asarray(v, dtype)


# ---------------------------------------------------------------- decode

decode_cases = st.tuples(
    st.integers(1, 4),  # batch
    st.sampled_from([(4, 4), (8, 8), (8, 2), (10, 10), (6, 3)]),  # (H, KH)
    st.sampled_from([8, 16]),  # block_size
    st.sampled_from([16, 32]),  # head_dim
    st.integers(0, 1000),  # seed
)


@settings(max_examples=25, deadline=None)
@given(
    decode_cases,
    st.sampled_from(["float32", "bfloat16"]),
    st.sampled_from(["stream", "gather"]),
)
def test_paged_decode_matches_ref(case, dtype_name, variant):
    batch, (H, KH), bs, D, seed = case
    dtype = jnp.dtype(dtype_name)
    rng = np.random.default_rng(seed)
    max_blocks = 6
    n_blocks = batch * max_blocks + 2
    kp, vp = make_pool(rng, n_blocks, bs, KH, D, dtype)
    bt = jnp.asarray(
        rng.permutation(n_blocks)[: batch * max_blocks].reshape(batch, max_blocks),
        jnp.int32,
    )
    # context lengths hit page boundaries: 1, bs, bs+1, full
    choices = [1, bs - 1, bs, bs + 1, 2 * bs, max_blocks * bs]
    lens = jnp.asarray(rng.choice(choices, batch), jnp.int32)
    q = jnp.asarray(rng.standard_normal((batch, H, D)), dtype)

    out = paged_attention_decode(q, kp, vp, bt, lens, variant=variant)
    expect = ref.ref_paged_attention_decode(q, kp, vp, bt, lens)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(expect, np.float32), **_tol(dtype)
    )


def test_decode_single_token_context():
    """ctx_len=1: attention over exactly the current token -> out == v."""
    rng = np.random.default_rng(7)
    kp, vp = make_pool(rng, 4, 8, 2, 16, jnp.float32)
    q = jnp.asarray(rng.standard_normal((1, 2, 16)), jnp.float32)
    bt = jnp.asarray([[2, 0, 1, 3]], jnp.int32)
    out = paged_attention_decode(q, kp, vp, bt, jnp.asarray([1], jnp.int32))
    np.testing.assert_allclose(out[0], vp[2, 0], rtol=1e-6, atol=1e-6)


def test_decode_ignores_stale_pool_contents():
    """Tokens beyond ctx_len (stale pages) must not affect the output."""
    rng = np.random.default_rng(8)
    kp, vp = make_pool(rng, 8, 8, 4, 16, jnp.float32)
    q = jnp.asarray(rng.standard_normal((1, 4, 16)), jnp.float32)
    bt = jnp.asarray([[0, 1, 2, 3]], jnp.int32)
    lens = jnp.asarray([11], jnp.int32)
    out1 = paged_attention_decode(q, kp, vp, bt, lens)
    # scribble over everything past position 11
    kp2 = kp.at[1, 3:].set(99.0).at[2:].set(-99.0)
    vp2 = vp.at[1, 3:].set(99.0).at[2:].set(-99.0)
    out2 = paged_attention_decode(q, kp2, vp2, bt, lens)
    np.testing.assert_allclose(out1, out2, rtol=1e-6, atol=1e-6)


def test_decode_jit_lowering_matches_eager():
    rng = np.random.default_rng(9)
    kp, vp = make_pool(rng, 12, 16, 8, 32, jnp.float32)
    q = jnp.asarray(rng.standard_normal((2, 8, 32)), jnp.float32)
    bt = jnp.asarray(rng.permutation(12)[:8].reshape(2, 4), jnp.int32)
    lens = jnp.asarray([5, 64], jnp.int32)
    eager = paged_attention_decode(q, kp, vp, bt, lens)
    jitted = jax.jit(paged_attention_decode)(q, kp, vp, bt, lens)
    np.testing.assert_allclose(eager, jitted, rtol=1e-6, atol=1e-6)


# ---------------------------------------------------------------- prefill

prefill_cases = st.tuples(
    st.integers(1, 24),  # chunk length T
    st.integers(0, 40),  # cache_len before chunk
    st.sampled_from([(4, 4), (8, 2), (6, 3)]),  # (H, KH)
    st.sampled_from([8, 16]),  # block_size
    st.integers(0, 1000),
)


@settings(max_examples=25, deadline=None)
@given(
    prefill_cases,
    st.sampled_from(["float32", "bfloat16"]),
    st.sampled_from(["stream", "gather"]),
)
def test_chunked_prefill_matches_ref(case, dtype_name, variant):
    T, cache, (H, KH), bs, seed = case
    dtype = jnp.dtype(dtype_name)
    rng = np.random.default_rng(seed)
    D = 16
    max_blocks = (cache + T + bs - 1) // bs + 1
    n_blocks = max_blocks + 3
    kp, vp = make_pool(rng, n_blocks, bs, KH, D, dtype)
    bt = jnp.asarray(rng.permutation(n_blocks)[:max_blocks], jnp.int32)
    q = jnp.asarray(rng.standard_normal((T, H, D)), dtype)

    out = chunked_prefill_attention(q, kp, vp, bt, cache, variant=variant)
    expect = ref.ref_chunked_prefill_attention(q, kp, vp, bt, cache)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(expect, np.float32), **_tol(dtype)
    )


def test_prefill_zero_cache_is_plain_causal():
    """cache_len=0 must equal dense causal attention over the chunk."""
    rng = np.random.default_rng(11)
    T, H, D, bs = 12, 4, 16, 8
    kp, vp = make_pool(rng, 4, bs, H, D, jnp.float32)
    bt = jnp.asarray([1, 3, 0, 2], jnp.int32)
    q = jnp.asarray(rng.standard_normal((T, H, D)), jnp.float32)
    out = chunked_prefill_attention(q, kp, vp, bt, 0)
    k = ref.gather_context(kp, bt, T)
    v = ref.gather_context(vp, bt, T)
    expect = ref.attention(q, k, v, jnp.arange(T))
    np.testing.assert_allclose(out, expect, rtol=2e-5, atol=2e-5)


def test_prefill_causality_last_token_invariant():
    """Changing the chunk's LAST key page slot must not affect earlier rows'
    outputs (strict causality inside the chunk)."""
    rng = np.random.default_rng(12)
    T, H, D, bs = 8, 4, 16, 8
    kp, vp = make_pool(rng, 4, bs, H, D, jnp.float32)
    bt = jnp.asarray([0, 1, 2, 3], jnp.int32)
    q = jnp.asarray(rng.standard_normal((T, H, D)), jnp.float32)
    out1 = chunked_prefill_attention(q, kp, vp, bt, 0)
    kp2 = kp.at[0, T - 1].set(42.0)
    vp2 = vp.at[0, T - 1].set(-42.0)
    out2 = chunked_prefill_attention(q, kp2, vp2, bt, 0)
    np.testing.assert_allclose(out1[: T - 1], out2[: T - 1], rtol=1e-6, atol=1e-6)
    assert not np.allclose(out1[T - 1], out2[T - 1])


def test_prefill_equals_decode_composition():
    """Prefilling T tokens must equal T successive decode steps (chunked
    recomputation restores exactly the state decode would have built)."""
    rng = np.random.default_rng(13)
    T, H, KH, D, bs = 10, 4, 2, 16, 8
    n_blocks, max_blocks = 6, 3
    kp, vp = make_pool(rng, n_blocks, bs, KH, D, jnp.float32)
    bt = jnp.asarray([4, 1, 5], jnp.int32)
    q = jnp.asarray(rng.standard_normal((T, H, D)), jnp.float32)

    chunk_out = chunked_prefill_attention(q, kp, vp, bt, 0)
    # decode path: one token at a time with growing ctx_len
    rows = []
    for i in range(T):
        o = paged_attention_decode(
            q[i : i + 1], kp, vp, bt[None], jnp.asarray([i + 1], jnp.int32)
        )
        rows.append(o[0])
    np.testing.assert_allclose(
        chunk_out, jnp.stack(rows), rtol=2e-5, atol=2e-5
    )


def test_stream_and_gather_variants_agree():
    """The TPU-shaped streaming kernel and the CPU gather lowering are the
    same function (DESIGN.md §Perf)."""
    rng = np.random.default_rng(99)
    B, H, KH, D, P, bs, MAXB = 2, 8, 2, 32, 32, 16, 6
    kp, vp = make_pool(rng, P, bs, KH, D, jnp.float32)
    q = jnp.asarray(rng.standard_normal((B, H, D)), jnp.float32)
    bt = jnp.asarray(rng.permutation(P)[: B * MAXB].reshape(B, MAXB), jnp.int32)
    lens = jnp.asarray([7, 77], jnp.int32)
    a = paged_attention_decode(q, kp, vp, bt, lens, variant="stream")
    b = paged_attention_decode(q, kp, vp, bt, lens, variant="gather")
    np.testing.assert_allclose(a, b, rtol=2e-5, atol=2e-5)
    qc = jnp.asarray(rng.standard_normal((9, H, D)), jnp.float32)
    a = chunked_prefill_attention(qc, kp, vp, bt[0], 21, variant="stream")
    b = chunked_prefill_attention(qc, kp, vp, bt[0], 21, variant="gather")
    np.testing.assert_allclose(a, b, rtol=2e-5, atol=2e-5)
