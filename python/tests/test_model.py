"""L2 model correctness: paged prefill/decode vs the dense oracle."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M

TOL = dict(rtol=5e-4, atol=5e-4)


def fresh_pools(cfg):
    return (
        jnp.zeros(cfg.pool_shape(), jnp.float32),
        jnp.zeros(cfg.pool_shape(), jnp.float32),
    )


def rand_block_table(cfg, rng):
    return jnp.asarray(
        rng.permutation(cfg.num_blocks)[: cfg.max_blocks_per_seq], jnp.int32
    )


@pytest.fixture(scope="module", params=list(M.MODELS))
def model(request):
    cfg = M.MODELS[request.param]
    return cfg, M.init_params(cfg, seed=0)


def test_param_flatten_order_covers_all_leaves(model):
    cfg, params = model
    order = M.param_flatten_order(cfg)
    leaves = jax.tree_util.tree_leaves(params)
    assert len(order) == len(leaves)
    for (name, shape, dtype), leaf in zip(order, leaves):
        assert tuple(leaf.shape) == tuple(shape), name
        assert str(leaf.dtype) == dtype, name


@pytest.mark.parametrize("chunk", [4, 7, 16])
def test_chunked_prefill_matches_dense(model, chunk):
    cfg, params = model
    rng = np.random.default_rng(chunk)
    L = 23
    toks = jnp.asarray(rng.integers(0, cfg.vocab, L), jnp.int32)
    dense = M.ref_forward_full(cfg, params, toks)

    kp, vp = fresh_pools(cfg)
    bt = rand_block_table(cfg, rng)
    cache, last = 0, None
    for s in range(0, L, chunk):
        piece = toks[s : s + chunk]
        chunk_logits, kp, vp = M.prefill_chunk(cfg, params, piece, kp, vp, bt, cache)
        cache += piece.shape[0]
        last = chunk_logits[-1]
        # every chunk's rows must match the dense forward at its positions
        np.testing.assert_allclose(
            chunk_logits, dense[s : s + piece.shape[0]], **TOL
        )
    np.testing.assert_allclose(last, dense[-1], **TOL)


def test_decode_after_prefill_matches_dense(model):
    cfg, params = model
    rng = np.random.default_rng(42)
    L = 12
    toks = jnp.asarray(rng.integers(0, cfg.vocab, L), jnp.int32)
    kp, vp = fresh_pools(cfg)
    bt = rand_block_table(cfg, rng)
    prefill_logits, kp, vp = M.prefill_chunk(cfg, params, toks, kp, vp, bt, 0)
    last = prefill_logits[-1]

    seq, cache = toks, L
    for _ in range(4):
        nxt = jnp.argmax(last).astype(jnp.int32)
        seq = jnp.concatenate([seq, nxt[None]])
        cache += 1
        logits, kp, vp = M.decode_step(
            cfg, params, nxt[None], kp, vp, bt[None],
            jnp.asarray([cache], jnp.int32),
        )
        last = logits[0]
        dense = M.ref_forward_full(cfg, params, seq)[-1]
        np.testing.assert_allclose(last, dense, **TOL)


def test_batched_decode_matches_per_sequence(model):
    """A batch-B decode step must equal B independent batch-1 steps."""
    cfg, params = model
    rng = np.random.default_rng(3)
    B = 4
    kp, vp = fresh_pools(cfg)
    bts, lens, toks = [], [], []
    blocks = rng.permutation(cfg.num_blocks)
    per = cfg.max_blocks_per_seq // B or 1
    cache_lens = [3, 9, 17, 33]
    for b in range(B):
        bt = np.full(cfg.max_blocks_per_seq, 0, np.int32)
        mine = blocks[b * 8 : b * 8 + 8]
        bt[: len(mine)] = mine
        bts.append(bt)
        lens.append(cache_lens[b])
        toks.append(rng.integers(0, cfg.vocab))
        # seed pools with random prior context for this sequence
        prior = jnp.asarray(rng.integers(0, cfg.vocab, cache_lens[b] - 1), jnp.int32)
        if cache_lens[b] > 1:
            _, kp, vp = M.prefill_chunk(
                cfg, params, prior, kp, vp, jnp.asarray(bt), 0
            )
    bts = jnp.asarray(np.stack(bts))
    lens_a = jnp.asarray(lens, jnp.int32)
    toks_a = jnp.asarray(toks, jnp.int32)

    batched, kp_b, vp_b = M.decode_step(cfg, params, toks_a, kp, vp, bts, lens_a)
    for b in range(B):
        single, _, _ = M.decode_step(
            cfg, params, toks_a[b : b + 1], kp, vp,
            bts[b : b + 1], lens_a[b : b + 1],
        )
        np.testing.assert_allclose(batched[b], single[0], **TOL)


def test_gqa_model_uses_fewer_kv_heads():
    cfg = M.MODELS["llama-mini"]
    assert cfg.n_kv_heads < cfg.n_heads
    assert cfg.pool_shape()[3] == cfg.n_kv_heads
    # KV bytes per token shrink by the GQA ratio vs an MHA twin
    mha = M.MODELS["gptj-mini"]
    assert cfg.kv_bytes_per_token() * (cfg.n_heads // cfg.n_kv_heads) == (
        mha.kv_bytes_per_token()
    )


def test_pool_untouched_blocks_preserved(model):
    """Prefill must only write pages in the sequence's block table."""
    cfg, params = model
    rng = np.random.default_rng(5)
    kp, vp = fresh_pools(cfg)
    kp = kp.at[:, -1].set(123.0)  # sentinel page not in the table
    bt = jnp.asarray(np.arange(cfg.max_blocks_per_seq), jnp.int32)  # excludes last
    toks = jnp.asarray(rng.integers(0, cfg.vocab, 9), jnp.int32)
    _, kp2, _ = M.prefill_chunk(cfg, params, toks, kp, vp, bt, 0)
    np.testing.assert_array_equal(kp2[:, -1], kp[:, -1])
