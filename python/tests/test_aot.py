"""AOT pipeline tests: lowering produces loadable HLO text + sound manifest."""

import json
import os

import numpy as np
import pytest

from compile import aot
from compile import model as M


@pytest.fixture(scope="module")
def tiny_build(tmp_path_factory):
    out_dir = tmp_path_factory.mktemp("artifacts")
    manifest_path = str(out_dir / "manifest.json")
    import sys
    argv = sys.argv
    sys.argv = [
        "aot", "--out", manifest_path, "--models", "gptj-mini",
        "--decode-batches", "1", "--prefill-chunks", "16",
    ]
    try:
        aot.main()
    finally:
        sys.argv = argv
    with open(manifest_path) as f:
        return str(out_dir), json.load(f)


def test_manifest_schema(tiny_build):
    out_dir, manifest = tiny_build
    entry = manifest["models"]["gptj-mini"]
    assert entry["config"]["block_size"] == 16
    assert entry["kv_bytes_per_token"] == M.MODELS["gptj-mini"].kv_bytes_per_token()
    assert set(entry["variants"]) == {"decode_b1", "prefill_t16"}
    for v in entry["variants"].values():
        assert os.path.exists(os.path.join(out_dir, v["file"]))


def test_hlo_text_is_parseable_entry(tiny_build):
    out_dir, manifest = tiny_build
    v = manifest["models"]["gptj-mini"]["variants"]["decode_b1"]
    text = open(os.path.join(out_dir, v["file"])).read()
    assert text.startswith("HloModule")
    assert "ENTRY" in text
    # weights are parameters, not constants: count parameter instructions
    n_params = len(manifest["models"]["gptj-mini"]["param_order"])
    assert text.count("parameter(") >= n_params + 5  # +operands


def test_params_npz_roundtrip(tiny_build):
    out_dir, manifest = tiny_build
    entry = manifest["models"]["gptj-mini"]
    data = np.load(os.path.join(out_dir, entry["params_npz"]))
    order = entry["param_order"]
    assert set(data.files) == {name for name, _, _ in order}
    for name, shape, dtype in order:
        assert data[name].shape == tuple(shape)
        assert str(data[name].dtype) == dtype


def test_lowered_decode_executes_like_eager():
    """Compile the lowered stablehlo back with jax and compare numerics —
    the same HLO text the Rust runtime will execute."""
    import jax
    import jax.numpy as jnp
    import functools

    cfg = M.MODELS["gptj-mini"]
    params = M.init_params(cfg, 0)
    rng = np.random.default_rng(0)
    B = 1
    toks = jnp.asarray([3], jnp.int32)
    kp = jnp.zeros(cfg.pool_shape(), jnp.float32)
    vp = jnp.zeros(cfg.pool_shape(), jnp.float32)
    bt = jnp.asarray(
        rng.permutation(cfg.num_blocks)[: cfg.max_blocks_per_seq].reshape(1, -1),
        jnp.int32,
    )
    lens = jnp.asarray([1], jnp.int32)

    fn = functools.partial(M.decode_step, cfg)
    eager_logits, _, _ = fn(params, toks, kp, vp, bt, lens)
    compiled = jax.jit(fn).lower(params, toks, kp, vp, bt, lens).compile()
    aot_logits, _, _ = compiled(params, toks, kp, vp, bt, lens)
    np.testing.assert_allclose(eager_logits, aot_logits, rtol=1e-5, atol=1e-5)
