"""AOT pipeline: lower every model variant to HLO *text* + params npz.

Build-time only (``make artifacts``); Python never runs on the request path.

Interchange format is HLO **text**, not ``.serialize()``: jax >= 0.5 emits
HloModuleProtos with 64-bit instruction ids which the Rust side's
xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Outputs under ``artifacts/``:
  * ``{model}_{variant}.hlo.txt`` — one per (model, decode batch | prefill
    chunk) combination; weights are *parameters* of the computation,
  * ``{model}.params.npz``        — weights, loaded by Rust `Literal::read_npz`,
  * ``manifest.json``             — configs, variant table, the exact input
    order (flattened params first, then positional operands) the Rust
    runtime must feed.
"""

from __future__ import annotations

import argparse
import functools
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from compile import model as M

DECODE_BATCHES = (1, 2, 4, 8)
PREFILL_CHUNKS = (16, 32, 64, 128)


def to_hlo_text(lowered) -> str:
    """stablehlo -> XlaComputation -> HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _abstract(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(shape, dtype)


def lower_decode(cfg: M.ModelConfig, batch: int) -> str:
    fn = functools.partial(M.decode_step, cfg)
    params = jax.eval_shape(lambda: M.init_params(cfg))
    lowered = jax.jit(fn).lower(
        params,
        _abstract((batch,), jnp.int32),
        _abstract(cfg.pool_shape()),
        _abstract(cfg.pool_shape()),
        _abstract((batch, cfg.max_blocks_per_seq), jnp.int32),
        _abstract((batch,), jnp.int32),
    )
    return to_hlo_text(lowered)


def lower_prefill(cfg: M.ModelConfig, chunk: int) -> str:
    fn = functools.partial(M.prefill_chunk, cfg)
    params = jax.eval_shape(lambda: M.init_params(cfg))
    lowered = jax.jit(fn).lower(
        params,
        _abstract((chunk,), jnp.int32),
        _abstract(cfg.pool_shape()),
        _abstract(cfg.pool_shape()),
        _abstract((cfg.max_blocks_per_seq,), jnp.int32),
        _abstract((), jnp.int32),
    )
    return to_hlo_text(lowered)


def write_params_npz(cfg: M.ModelConfig, path: str, seed: int) -> None:
    params = M.init_params(cfg, seed)
    leaves = jax.tree_util.tree_flatten_with_path(params)[0]
    arrays = {}
    for p, leaf in leaves:
        name = ".".join(str(getattr(seg, "key", seg)) for seg in p)
        arrays[name] = np.asarray(leaf)
    np.savez(path, **arrays)


def build_model(cfg: M.ModelConfig, out_dir: str, seed: int,
                decode_batches, prefill_chunks) -> dict:
    entry: dict = {
        "config": {
            "name": cfg.name,
            "n_layers": cfg.n_layers,
            "d_model": cfg.d_model,
            "n_heads": cfg.n_heads,
            "n_kv_heads": cfg.n_kv_heads,
            "head_dim": cfg.head_dim,
            "d_ff": cfg.d_ff,
            "vocab": cfg.vocab,
            "block_size": cfg.block_size,
            "num_blocks": cfg.num_blocks,
            "max_blocks_per_seq": cfg.max_blocks_per_seq,
        },
        "kv_bytes_per_token": cfg.kv_bytes_per_token(),
        "param_order": M.param_flatten_order(cfg),
        "params_npz": f"{cfg.name}.params.npz",
        "variants": {},
    }
    write_params_npz(cfg, os.path.join(out_dir, entry["params_npz"]), seed)

    for b in decode_batches:
        t0 = time.time()
        text = lower_decode(cfg, b)
        name = f"decode_b{b}"
        fname = f"{cfg.name}_{name}.hlo.txt"
        with open(os.path.join(out_dir, fname), "w") as f:
            f.write(text)
        entry["variants"][name] = {"file": fname, "kind": "decode", "batch": b}
        print(f"  {cfg.name}/{name}: {len(text)/1e6:.2f} MB HLO "
              f"({time.time()-t0:.1f}s)")
    for t in prefill_chunks:
        t0 = time.time()
        text = lower_prefill(cfg, t)
        name = f"prefill_t{t}"
        fname = f"{cfg.name}_{name}.hlo.txt"
        with open(os.path.join(out_dir, fname), "w") as f:
            f.write(text)
        entry["variants"][name] = {"file": fname, "kind": "prefill", "chunk": t}
        print(f"  {cfg.name}/{name}: {len(text)/1e6:.2f} MB HLO "
              f"({time.time()-t0:.1f}s)")
    return entry


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts/manifest.json",
                    help="manifest path; artifacts land in its directory")
    ap.add_argument("--models", nargs="*", default=list(M.MODELS),
                    help=f"subset of {list(M.MODELS)}")
    ap.add_argument("--decode-batches", nargs="*", type=int,
                    default=list(DECODE_BATCHES))
    ap.add_argument("--prefill-chunks", nargs="*", type=int,
                    default=list(PREFILL_CHUNKS))
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    out_dir = os.path.dirname(os.path.abspath(args.out))
    os.makedirs(out_dir, exist_ok=True)

    manifest = {"format": 1, "models": {}}
    for name in args.models:
        print(f"lowering {name} ...")
        manifest["models"][name] = build_model(
            M.MODELS[name], out_dir, args.seed,
            args.decode_batches, args.prefill_chunks,
        )
    with open(args.out, "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
