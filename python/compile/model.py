"""L2: the JAX model — a paged-KV-cache transformer built on the L1 kernels.

This is the compute graph the Rust coordinator drives at runtime. Two entry
points are AOT-lowered per model variant (see `aot.py`):

  * ``decode_step``    — one token for each of B running sequences,
  * ``prefill_chunk``  — T prompt/recompute tokens for ONE sequence
                          (InferCept's chunked recomputation primitive, §4.2).

Both read and write the paged KV pool (`[L, P, bs, KH, D]`) addressed through
block tables, so the Rust block allocator fully owns memory placement. The
layer stack runs under ``lax.scan`` over stacked per-layer parameters — this
keeps the lowered HLO small and AOT time flat in depth (see DESIGN.md §Perf).

Weights are *inputs*, not baked constants: `aot.py` writes them to an ``.npz``
that the Rust runtime loads with ``Literal::read_npz`` and feeds in the
flatten order recorded in the manifest.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from compile.kernels import chunked_prefill_attention, paged_attention_decode

Params = Dict[str, Any]


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """Geometry of one mini model + its paged KV pool.

    The minis stand in for the paper's GPT-J-6B / Vicuna-13B / Llama3-70B:
    scheduling is content-agnostic, so only shapes, timings, and memory
    footprints matter (DESIGN.md §4). ``llama-mini`` keeps the GQA ratio that
    drives the paper's 70B Preserve/Swap behaviour.
    """

    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    d_ff: int
    vocab: int
    # paged KV pool geometry — must match the Rust allocator's config
    block_size: int = 16
    num_blocks: int = 128
    max_blocks_per_seq: int = 32
    rope_theta: float = 10000.0
    # Kernel lowering used by the AOT artifacts: "gather" (CPU-fast) or
    # "stream" (the TPU-shaped page-streaming kernel). See DESIGN.md §Perf.
    attn_variant: str = "gather"

    @property
    def max_seq_len(self) -> int:
        return self.block_size * self.max_blocks_per_seq

    def pool_shape(self) -> Tuple[int, int, int, int, int]:
        return (
            self.n_layers,
            self.num_blocks,
            self.block_size,
            self.n_kv_heads,
            self.head_dim,
        )

    def kv_bytes_per_token(self) -> int:
        """f32 KV bytes per cached token across all layers (the paper's M)."""
        return self.n_layers * 2 * self.n_kv_heads * self.head_dim * 4


MODELS: Dict[str, ModelConfig] = {
    # GPT-J-6B stand-in (MHA)
    "gptj-mini": ModelConfig(
        name="gptj-mini", n_layers=4, d_model=256, n_heads=8, n_kv_heads=8,
        head_dim=32, d_ff=1024, vocab=512,
    ),
    # Vicuna-13B stand-in (MHA, deeper/wider)
    "vicuna-mini": ModelConfig(
        name="vicuna-mini", n_layers=6, d_model=320, n_heads=10, n_kv_heads=10,
        head_dim=32, d_ff=1280, vocab=512,
    ),
    # Llama3-70B stand-in — preserves the 4:1 GQA compression (§5.1 70B).
    "llama-mini": ModelConfig(
        name="llama-mini", n_layers=4, d_model=256, n_heads=8, n_kv_heads=2,
        head_dim=32, d_ff=1024, vocab=512,
    ),
}


def init_params(cfg: ModelConfig, seed: int = 0) -> Params:
    """Random init; per-layer weights stacked on a leading L axis for scan."""
    key = jax.random.PRNGKey(seed)
    ks = jax.random.split(key, 8)
    L, d, ff = cfg.n_layers, cfg.d_model, cfg.d_ff
    qd = cfg.n_heads * cfg.head_dim
    kvd = cfg.n_kv_heads * cfg.head_dim

    def norm_init(k, *shape):
        return jax.random.normal(k, shape, jnp.float32) / jnp.sqrt(shape[-2])

    return {
        "embed": jax.random.normal(ks[0], (cfg.vocab, d), jnp.float32) * 0.02,
        "ln_f": jnp.ones((d,), jnp.float32),
        "layers": {
            "ln1": jnp.ones((L, d), jnp.float32),
            "ln2": jnp.ones((L, d), jnp.float32),
            "wq": norm_init(ks[1], L, d, qd),
            "wk": norm_init(ks[2], L, d, kvd),
            "wv": norm_init(ks[3], L, d, kvd),
            "wo": norm_init(ks[4], L, qd, d),
            "w_gate": norm_init(ks[5], L, d, ff),
            "w_up": norm_init(ks[6], L, d, ff),
            "w_down": norm_init(ks[7], L, ff, d),
        },
    }


def param_flatten_order(cfg: ModelConfig) -> list:
    """(name, shape, dtype) in jax pytree flatten order — recorded in the
    manifest so the Rust runtime feeds the npz entries correctly."""
    params = jax.eval_shape(lambda: init_params(cfg))
    leaves = jax.tree_util.tree_flatten_with_path(params)[0]
    out = []
    for path, leaf in leaves:
        name = ".".join(str(getattr(p, "key", p)) for p in path)
        out.append((name, tuple(leaf.shape), str(leaf.dtype)))
    return out


def _rms_norm(x: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    return x * lax.rsqrt(var + 1e-6) * scale


def _rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """Rotary embedding. x: [T, H, D], positions: [T]."""
    head_dim = x.shape[-1]
    freqs = 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))
    angles = positions[:, None].astype(jnp.float32) * freqs[None, :]  # [T, D/2]
    cos, sin = jnp.cos(angles)[:, None, :], jnp.sin(angles)[:, None, :]
    x1, x2 = x[..., 0::2], x[..., 1::2]
    rx1 = x1 * cos - x2 * sin
    rx2 = x1 * sin + x2 * cos
    return jnp.stack([rx1, rx2], axis=-1).reshape(x.shape)


def _qkv(cfg, lp, h, positions):
    """Project + rope. h: [T, d] -> q [T,H,D], k/v [T,KH,D]."""
    T = h.shape[0]
    q = (h @ lp["wq"]).reshape(T, cfg.n_heads, cfg.head_dim)
    k = (h @ lp["wk"]).reshape(T, cfg.n_kv_heads, cfg.head_dim)
    v = (h @ lp["wv"]).reshape(T, cfg.n_kv_heads, cfg.head_dim)
    return _rope(q, positions, cfg.rope_theta), _rope(k, positions, cfg.rope_theta), v


def _mlp(lp, x):
    h = _rms_norm(x, lp["ln2"])
    return x + (jax.nn.silu(h @ lp["w_gate"]) * (h @ lp["w_up"])) @ lp["w_down"]


def decode_step(
    cfg: ModelConfig,
    params: Params,
    token_ids: jnp.ndarray,  # [B] i32
    k_pool: jnp.ndarray,  # [L, P, bs, KH, D]
    v_pool: jnp.ndarray,
    block_tables: jnp.ndarray,  # [B, MAXB] i32
    ctx_lens: jnp.ndarray,  # [B] i32 — INCLUDING the token decoded now
) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """One decode iteration for B sequences. Returns (logits, k_pool, v_pool)."""
    positions = ctx_lens - 1  # [B]
    x = params["embed"][token_ids]  # [B, d]
    blocks = jnp.take_along_axis(
        block_tables, (positions // cfg.block_size)[:, None], axis=1
    )[:, 0]  # [B]
    offsets = positions % cfg.block_size  # [B]

    def layer(x, scanned):
        lp, kp_l, vp_l = scanned
        h = _rms_norm(x, lp["ln1"])
        q, k, v = _qkv(cfg, lp, h, positions)
        # Write this token's KV into its page before attending.
        kp_l = kp_l.at[blocks, offsets].set(k)
        vp_l = vp_l.at[blocks, offsets].set(v)
        attn = paged_attention_decode(
            q, kp_l, vp_l, block_tables, ctx_lens, variant=cfg.attn_variant
        )
        x = x + attn.reshape(x.shape[0], -1) @ lp["wo"]
        x = _mlp(lp, x)
        return x, (kp_l, vp_l)

    x, (k_pool, v_pool) = lax.scan(
        layer, x, (params["layers"], k_pool, v_pool)
    )
    logits = _rms_norm(x, params["ln_f"]) @ params["embed"].T  # [B, V]
    return logits, k_pool, v_pool


def prefill_chunk(
    cfg: ModelConfig,
    params: Params,
    token_ids: jnp.ndarray,  # [T] i32
    k_pool: jnp.ndarray,  # [L, P, bs, KH, D]
    v_pool: jnp.ndarray,
    block_table: jnp.ndarray,  # [MAXB] i32
    cache_len: jnp.ndarray,  # scalar i32 — tokens already cached BEFORE chunk
) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Prefill/recompute one chunk of one sequence.

    Returns (logits [T, V], k_pool, v_pool). Only the final chunk's logits
    are consumed (row `real_len - 1`, to sample the first generated token —
    full rows are returned because the Rust engine pads chunks to compiled
    sizes); earlier chunks run purely to rebuild KV — exactly the §4.2
    recomputation semantics.
    """
    T = token_ids.shape[0]
    cache_len = jnp.asarray(cache_len, jnp.int32)
    positions = cache_len + jnp.arange(T, dtype=jnp.int32)  # [T]
    x = params["embed"][token_ids]  # [T, d]
    blocks = block_table[positions // cfg.block_size]  # [T]
    offsets = positions % cfg.block_size

    def layer(x, scanned):
        lp, kp_l, vp_l = scanned
        h = _rms_norm(x, lp["ln1"])
        q, k, v = _qkv(cfg, lp, h, positions)
        kp_l = kp_l.at[blocks, offsets].set(k)
        vp_l = vp_l.at[blocks, offsets].set(v)
        attn = chunked_prefill_attention(
            q, kp_l, vp_l, block_table, cache_len, variant=cfg.attn_variant
        )
        x = x + attn.reshape(T, -1) @ lp["wo"]
        x = _mlp(lp, x)
        return x, (kp_l, vp_l)

    x, (k_pool, v_pool) = lax.scan(
        layer, x, (params["layers"], k_pool, v_pool)
    )
    logits = _rms_norm(x, params["ln_f"]) @ params["embed"].T  # [T, V]
    return logits, k_pool, v_pool


def ref_forward_full(
    cfg: ModelConfig, params: Params, token_ids: jnp.ndarray
) -> jnp.ndarray:
    """Oracle: dense causal forward over the whole sequence, no paging.

    Used by tests to validate that any composition of prefill chunks and
    decode steps through the paged pool reproduces the dense computation.
    """
    from compile.kernels import ref

    T = token_ids.shape[0]
    positions = jnp.arange(T, dtype=jnp.int32)
    x = params["embed"][token_ids]

    def layer(x, lp):
        h = _rms_norm(x, lp["ln1"])
        q, k, v = _qkv(cfg, lp, h, positions)
        attn = ref.attention(q, k, v, positions)
        x = x + attn.reshape(T, -1) @ lp["wo"]
        x = _mlp(lp, x)
        return x, None

    x, _ = lax.scan(layer, x, params["layers"])
    return _rms_norm(x, params["ln_f"]) @ params["embed"].T  # [T, V]
