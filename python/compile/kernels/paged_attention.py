"""L1 Pallas kernels: paged attention for decode and chunked prefill.

These are the compute hot-spots of InferCept's serving path. The KV cache
lives in a *paged pool* — `[num_blocks, block_size, kv_heads, head_dim]` per
layer — and sequences address it through per-sequence block tables, exactly
mirroring the L3 Rust block allocator (the L3 block size IS the L1 tile minor
dimension; see DESIGN.md §3 Hardware-Adaptation).

TPU mapping of the paper's CUDA PagedAttention:
  * one grid step per sequence stages one KV *page* at a time (HBM -> VMEM
    via the BlockSpec schedule, instead of threadblock/shared-memory tiles),
  * qk^T and alpha*V per page are expressed as (heads x head_dim) matmuls so
    the MXU systolic array does the work (instead of warp-level dots),
  * an online (flash-style) softmax streams arbitrary context lengths
    through fixed VMEM: running max `m`, denominator `l`, accumulator `acc`.

All kernels are lowered with interpret=True — the CPU PJRT plugin cannot run
Mosaic custom-calls; numerics are validated against `ref.py` by pytest.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl

NEG_INF = float("-inf")


def _expand_kv(x: jnp.ndarray, n_heads: int) -> jnp.ndarray:
    """Expand grouped KV heads [..., kv_heads, d] to [..., n_heads, d] (GQA)."""
    kv_heads = x.shape[-2]
    if kv_heads == n_heads:
        return x
    assert n_heads % kv_heads == 0, (n_heads, kv_heads)
    return jnp.repeat(x, n_heads // kv_heads, axis=-2)


def _decode_kernel(
    q_ref,  # [1, H, D]
    bt_ref,  # [1, MAXB] i32
    len_ref,  # [1] i32
    k_pool_ref,  # [P, bs, KH, D]
    v_pool_ref,  # [P, bs, KH, D]
    o_ref,  # [1, H, D]
    *,
    block_size: int,
    n_heads: int,
):
    q = q_ref[0].astype(jnp.float32)  # [H, D]
    head_dim = q.shape[-1]
    scale = 1.0 / (head_dim**0.5)
    ctx_len = len_ref[0]
    n_pages = (ctx_len + block_size - 1) // block_size

    m0 = jnp.full((n_heads,), NEG_INF, dtype=jnp.float32)
    l0 = jnp.zeros((n_heads,), dtype=jnp.float32)
    acc0 = jnp.zeros((n_heads, head_dim), dtype=jnp.float32)

    def page_step(j, carry):
        m, l, acc = carry
        page = bt_ref[0, j]
        # Stage one KV page. On TPU this is the HBM->VMEM copy of a
        # [block_size, KH, D] tile; double-buffering would prefetch j+1.
        k = pl.load(k_pool_ref, (pl.dslice(page, 1),))[0]  # [bs, KH, D]
        v = pl.load(v_pool_ref, (pl.dslice(page, 1),))[0]
        k = _expand_kv(k.astype(jnp.float32), n_heads)  # [bs, H, D]
        v = _expand_kv(v.astype(jnp.float32), n_heads)
        # MXU-shaped: per head, [1, D] @ [D, bs].
        s = jnp.einsum("hd,thd->ht", q, k) * scale  # [H, bs]
        pos = j * block_size + lax.iota(jnp.int32, block_size)
        s = jnp.where(pos[None, :] < ctx_len, s, NEG_INF)
        m_new = jnp.maximum(m, s.max(axis=1))
        p = jnp.exp(s - m_new[:, None])  # [H, bs]
        alpha = jnp.exp(m - m_new)
        l_new = l * alpha + p.sum(axis=1)
        acc_new = acc * alpha[:, None] + jnp.einsum("ht,thd->hd", p, v)
        return m_new, l_new, acc_new

    m, l, acc = lax.fori_loop(0, n_pages, page_step, (m0, l0, acc0))
    out = acc / jnp.maximum(l, 1e-30)[:, None]
    o_ref[0] = out.astype(o_ref.dtype)


def _decode_gather_kernel(
    q_ref,  # [1, H, D]
    bt_ref,  # [1, MAXB] i32
    len_ref,  # [1] i32
    k_pool_ref,  # [P, bs, KH, D]
    v_pool_ref,  # [P, bs, KH, D]
    o_ref,  # [1, H, D]
    *,
    block_size: int,
    n_heads: int,
):
    """Gather-lowering of the decode kernel: one pool gather per sequence
    instead of a page-streaming loop. Numerically identical to
    [`_decode_kernel`]; this variant is what CPU-PJRT artifacts use — the
    XLA CPU backend executes a single fused gather+GEMM far faster than a
    32-iteration while loop (see DESIGN.md §Perf). On TPU the streaming
    kernel is the deployment target."""
    q = q_ref[0].astype(jnp.float32)  # [H, D]
    head_dim = q.shape[-1]
    scale = 1.0 / (head_dim**0.5)
    ctx_len = len_ref[0]
    pages = bt_ref[0]  # [MAXB]
    # jnp.take over the materialized pool ref: XLA fuses this into a single
    # gather (pl.load with array indices has no interpret discharge rule).
    k = jnp.take(k_pool_ref[...], pages, axis=0).astype(jnp.float32)
    v = jnp.take(v_pool_ref[...], pages, axis=0).astype(jnp.float32)
    maxb, bs = k.shape[0], k.shape[1]
    k = _expand_kv(k.reshape(maxb * bs, *k.shape[2:]), n_heads)  # [T, H, D]
    v = _expand_kv(v.reshape(maxb * bs, *v.shape[2:]), n_heads)
    s = jnp.einsum("hd,thd->ht", q, k) * scale  # [H, T]
    pos = lax.iota(jnp.int32, maxb * bs)
    s = jnp.where(pos[None, :] < ctx_len, s, NEG_INF)
    m = s.max(axis=1, keepdims=True)
    p = jnp.exp(s - m)
    out = jnp.einsum("ht,thd->hd", p, v) / jnp.maximum(p.sum(axis=1), 1e-30)[:, None]
    o_ref[0] = out.astype(o_ref.dtype)


def paged_attention_decode(
    q: jnp.ndarray,  # [B, H, D]
    k_pool: jnp.ndarray,  # [P, bs, KH, D]
    v_pool: jnp.ndarray,  # [P, bs, KH, D]
    block_tables: jnp.ndarray,  # [B, MAXB] i32
    ctx_lens: jnp.ndarray,  # [B] i32 — valid tokens incl. the current one
    variant: str = "stream",
) -> jnp.ndarray:
    """Single-token paged attention over a batch of sequences.

    `ctx_lens[b]` counts the tokens already written to the pool for sequence
    `b`, including the token whose query this is (the engine writes the new
    KV before attending, so decode attends to its own position too).

    `variant="stream"` is the TPU-shaped page-streaming kernel (fixed VMEM,
    online softmax); `variant="gather"` is the CPU-fast lowering used by the
    AOT artifacts. Both are validated against `ref.py`.
    """
    batch, n_heads, head_dim = q.shape
    n_pages_pool, block_size = k_pool.shape[0], k_pool.shape[1]
    max_blocks = block_tables.shape[1]

    body = _decode_gather_kernel if variant == "gather" else _decode_kernel
    kernel = functools.partial(body, block_size=block_size, n_heads=n_heads)
    return pl.pallas_call(
        kernel,
        grid=(batch,),
        in_specs=[
            pl.BlockSpec((1, n_heads, head_dim), lambda b: (b, 0, 0)),
            pl.BlockSpec((1, max_blocks), lambda b: (b, 0)),
            pl.BlockSpec((1,), lambda b: (b,)),
            pl.BlockSpec(k_pool.shape, lambda b: (0, 0, 0, 0)),
            pl.BlockSpec(v_pool.shape, lambda b: (0, 0, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, n_heads, head_dim), lambda b: (b, 0, 0)),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        interpret=True,
    )(q, block_tables, ctx_lens, k_pool, v_pool)


def _prefill_kernel(
    q_ref,  # [T, H, D]
    bt_ref,  # [MAXB] i32
    len_ref,  # [1] i32 — cache length BEFORE this chunk
    k_pool_ref,
    v_pool_ref,
    o_ref,  # [T, H, D]
    *,
    block_size: int,
    n_heads: int,
):
    q = q_ref[...].astype(jnp.float32)  # [T, H, D]
    chunk, _, head_dim = q.shape
    scale = 1.0 / (head_dim**0.5)
    cache_len = len_ref[0]
    total = cache_len + chunk
    n_pages = (total + block_size - 1) // block_size
    q_pos = cache_len + lax.iota(jnp.int32, chunk)  # global position per query

    m0 = jnp.full((chunk, n_heads), NEG_INF, dtype=jnp.float32)
    l0 = jnp.zeros((chunk, n_heads), dtype=jnp.float32)
    acc0 = jnp.zeros((chunk, n_heads, head_dim), dtype=jnp.float32)

    def page_step(j, carry):
        m, l, acc = carry
        page = bt_ref[j]
        k = pl.load(k_pool_ref, (pl.dslice(page, 1),))[0]
        v = pl.load(v_pool_ref, (pl.dslice(page, 1),))[0]
        k = _expand_kv(k.astype(jnp.float32), n_heads)
        v = _expand_kv(v.astype(jnp.float32), n_heads)
        s = jnp.einsum("qhd,thd->qht", q, k) * scale  # [T, H, bs]
        pos = j * block_size + lax.iota(jnp.int32, block_size)
        # Causal within the chunk, full visibility of the prior cache:
        # query i (global q_pos[i]) sees keys at positions <= q_pos[i].
        visible = pos[None, :] <= q_pos[:, None]  # [T, bs]
        s = jnp.where(visible[:, None, :], s, NEG_INF)
        m_new = jnp.maximum(m, s.max(axis=2))
        p = jnp.exp(s - m_new[..., None])
        alpha = jnp.exp(m - m_new)
        l_new = l * alpha + p.sum(axis=2)
        acc_new = acc * alpha[..., None] + jnp.einsum("qht,thd->qhd", p, v)
        return m_new, l_new, acc_new

    m, l, acc = lax.fori_loop(0, n_pages, page_step, (m0, l0, acc0))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    o_ref[...] = out.astype(o_ref.dtype)


def _prefill_gather_kernel(
    q_ref,  # [T, H, D]
    bt_ref,  # [MAXB] i32
    len_ref,  # [1] i32 — cache length BEFORE this chunk
    k_pool_ref,
    v_pool_ref,
    o_ref,  # [T, H, D]
    *,
    block_size: int,
    n_heads: int,
):
    """Gather-lowering of the prefill kernel (see `_decode_gather_kernel`)."""
    q = q_ref[...].astype(jnp.float32)  # [T, H, D]
    chunk, _, head_dim = q.shape
    scale = 1.0 / (head_dim**0.5)
    cache_len = len_ref[0]
    q_pos = cache_len + lax.iota(jnp.int32, chunk)
    pages = bt_ref[...]
    k = jnp.take(k_pool_ref[...], pages, axis=0).astype(jnp.float32)
    v = jnp.take(v_pool_ref[...], pages, axis=0).astype(jnp.float32)
    maxb, bs = k.shape[0], k.shape[1]
    k = _expand_kv(k.reshape(maxb * bs, *k.shape[2:]), n_heads)  # [S, H, D]
    v = _expand_kv(v.reshape(maxb * bs, *v.shape[2:]), n_heads)
    s = jnp.einsum("qhd,thd->qht", q, k) * scale  # [T, H, S]
    pos = lax.iota(jnp.int32, maxb * bs)
    visible = pos[None, :] <= q_pos[:, None]  # [T, S]
    s = jnp.where(visible[:, None, :], s, NEG_INF)
    m = s.max(axis=2, keepdims=True)
    p = jnp.exp(s - m)
    out = jnp.einsum("qht,thd->qhd", p, v) / jnp.maximum(
        p.sum(axis=2), 1e-30
    )[..., None]
    o_ref[...] = out.astype(o_ref.dtype)


def chunked_prefill_attention(
    q: jnp.ndarray,  # [T, H, D]
    k_pool: jnp.ndarray,  # [P, bs, KH, D]
    v_pool: jnp.ndarray,
    block_table: jnp.ndarray,  # [MAXB] i32
    cache_len: jnp.ndarray,  # scalar i32 — tokens before this chunk
    variant: str = "stream",
) -> jnp.ndarray:
    """Attention for one prefill/recompute chunk of a single sequence.

    The chunk's own KV must already be written to the pool at positions
    `cache_len .. cache_len+T-1`. This is exactly InferCept's recomputation
    chunking primitive (§4.2): re-running a discarded context S tokens at a
    time, each chunk attending to everything recomputed so far.
    """
    chunk, n_heads, head_dim = q.shape
    block_size = k_pool.shape[1]
    max_blocks = block_table.shape[0]
    cache_len = jnp.asarray(cache_len, jnp.int32).reshape((1,))

    body = _prefill_gather_kernel if variant == "gather" else _prefill_kernel
    kernel = functools.partial(body, block_size=block_size, n_heads=n_heads)
    return pl.pallas_call(
        kernel,
        grid=(1,),
        in_specs=[
            pl.BlockSpec(q.shape, lambda i: (0, 0, 0)),
            pl.BlockSpec((max_blocks,), lambda i: (0,)),
            pl.BlockSpec((1,), lambda i: (0,)),
            pl.BlockSpec(k_pool.shape, lambda i: (0, 0, 0, 0)),
            pl.BlockSpec(v_pool.shape, lambda i: (0, 0, 0, 0)),
        ],
        out_specs=pl.BlockSpec(q.shape, lambda i: (0, 0, 0)),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        interpret=True,
    )(q, block_table, cache_len, k_pool, v_pool)
