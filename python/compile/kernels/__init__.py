"""L1: Pallas kernels for InferCept-RS's compute hot-spots."""

from compile.kernels.paged_attention import (
    chunked_prefill_attention,
    paged_attention_decode,
)

__all__ = ["paged_attention_decode", "chunked_prefill_attention"]
