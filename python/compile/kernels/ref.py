"""Pure-jnp oracles for the Pallas kernels.

Dense, gather-based attention with no paging tricks — the correctness signal
every kernel change is validated against (pytest + hypothesis sweeps).
"""

from __future__ import annotations

import jax.numpy as jnp


def gather_context(
    pool: jnp.ndarray,  # [P, bs, KH, D]
    block_table: jnp.ndarray,  # [MAXB] i32
    length: int,
) -> jnp.ndarray:
    """Materialize the first `length` cached tokens of one sequence."""
    block_size = pool.shape[1]
    n = int(length)
    idx = jnp.arange(n)
    blocks = block_table[idx // block_size]
    offsets = idx % block_size
    return pool[blocks, offsets]  # [length, KH, D]


def _expand_kv(x: jnp.ndarray, n_heads: int) -> jnp.ndarray:
    kv_heads = x.shape[-2]
    if kv_heads == n_heads:
        return x
    return jnp.repeat(x, n_heads // kv_heads, axis=-2)


def attention(
    q: jnp.ndarray,  # [T, H, D] — queries at global positions q_pos
    k: jnp.ndarray,  # [S, KH, D] — full context keys
    v: jnp.ndarray,  # [S, KH, D]
    q_pos: jnp.ndarray,  # [T] global positions of the queries
) -> jnp.ndarray:
    """Masked attention: query i sees keys at positions <= q_pos[i]."""
    n_heads, head_dim = q.shape[1], q.shape[2]
    k = _expand_kv(k, n_heads).astype(jnp.float32)
    v = _expand_kv(v, n_heads).astype(jnp.float32)
    qf = q.astype(jnp.float32)
    s = jnp.einsum("qhd,shd->qhs", qf, k) / (head_dim**0.5)
    pos = jnp.arange(k.shape[0])
    mask = pos[None, None, :] <= q_pos[:, None, None]
    s = jnp.where(mask, s, -jnp.inf)
    p = jnp.exp(s - s.max(axis=-1, keepdims=True))
    p = p / p.sum(axis=-1, keepdims=True)
    return jnp.einsum("qhs,shd->qhd", p, v).astype(q.dtype)


def ref_paged_attention_decode(
    q: jnp.ndarray,  # [B, H, D]
    k_pool: jnp.ndarray,
    v_pool: jnp.ndarray,
    block_tables: jnp.ndarray,  # [B, MAXB]
    ctx_lens,  # [B] python ints / array
) -> jnp.ndarray:
    outs = []
    for b in range(q.shape[0]):
        n = int(ctx_lens[b])
        k = gather_context(k_pool, block_tables[b], n)
        v = gather_context(v_pool, block_tables[b], n)
        o = attention(q[b : b + 1], k, v, jnp.array([n - 1]))
        outs.append(o[0])
    return jnp.stack(outs)


def ref_chunked_prefill_attention(
    q: jnp.ndarray,  # [T, H, D]
    k_pool: jnp.ndarray,
    v_pool: jnp.ndarray,
    block_table: jnp.ndarray,  # [MAXB]
    cache_len: int,
) -> jnp.ndarray:
    chunk = q.shape[0]
    total = int(cache_len) + chunk
    k = gather_context(k_pool, block_table, total)
    v = gather_context(v_pool, block_table, total)
    q_pos = int(cache_len) + jnp.arange(chunk)
    return attention(q, k, v, q_pos)
