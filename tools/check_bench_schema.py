#!/usr/bin/env python3
"""Validate a BENCH_sched.json produced by `cargo bench --bench
bench_planner_e2e` (see rust/src/util/bench.rs for the writer).

Usage:
    check_bench_schema.py [--allow-placeholder] [PATH]

PATH defaults to BENCH_sched.json at the repo root. By default the file
must contain real measurements: every expected result row and derived key
present, with positive timings. `--allow-placeholder` additionally accepts
the committed pending-first-measurement stub (empty results) — that mode is
for validating the *tracked* file; CI validates the freshly *generated*
file strictly, right after running the bench.

Exit status 0 on success, 1 with per-problem messages otherwise.
"""

import json
import sys
from pathlib import Path

PLACEHOLDER_PROFILE = "pending-first-measurement"

# One row per bench.run() call in rust/benches/bench_planner_e2e.rs.
EXPECTED_RESULTS = [
    "planner_e2e/capture+plan 256r/128p/512w/32s",
    "planner_e2e/capture 256r/128p/512w/32s",
    "planner_e2e/capture aged-10k 256r/128p/512w/32s",
    "planner_e2e/plan 256r/128p/512w/32s",
    "planner_e2e/capture_hashmap_baseline 256r/128p/512w/32s",
    "planner_e2e/delta_capture+plan 256r/128p/512w/32s",
    "planner_e2e/delta_capture+plan 256r/128p/10000w/32s",
    "planner_e2e/capture 256r/128p/10000w/32s",
    "planner_e2e/sim_replay mixed120@3rps infercept",
    "planner_e2e/shared_prefix 32x512t infercept",
    "planner_e2e/speculation 16x300ms infercept",
]

EXPECTED_DERIVED = [
    "capture_speedup_vs_hashmap",
    "capture_aged_over_fresh",
    "capture_plan_cycle_us",
    "delta_cycle_us",
    "stress_10k_delta_cycle_us",
    "stress_10k_over_512_delta_cycle",
    "delta_over_full_cycle",
    "stress_10k_full_capture_over_delta_cycle",
    "sim_replay_iters_per_sec",
    "sim_replay_iterations",
    "shared_prefix_block_ratio",
    "shared_prefix_hits",
    "shared_prefix_cow_copies",
    "speculation_salvage_ratio",
    "speculations_started",
    "speculation_salvaged_tokens",
]

RESULT_FIELDS = ["name", "iters", "mean_ns", "p50_ns", "p95_ns"]


def check(path: Path, allow_placeholder: bool) -> list[str]:
    errors: list[str] = []
    try:
        data = json.loads(path.read_text())
    except (OSError, json.JSONDecodeError) as e:
        return [f"{path}: unreadable or invalid JSON: {e}"]

    for key in ("suite", "profile", "results", "derived"):
        if key not in data:
            errors.append(f"missing top-level key: {key!r}")
    if errors:
        return errors

    if data["suite"] != "bench_planner_e2e":
        errors.append(f"suite is {data['suite']!r}, expected 'bench_planner_e2e'")
    if not isinstance(data["results"], list):
        return errors + ["'results' is not a list"]
    if not isinstance(data["derived"], dict):
        return errors + ["'derived' is not an object"]

    placeholder = data["profile"] == PLACEHOLDER_PROFILE or not data["results"]
    if placeholder:
        if allow_placeholder:
            return errors
        errors.append(
            "placeholder report (no measurements); run "
            "`cd rust && cargo bench --bench bench_planner_e2e` first"
        )
        return errors

    names = []
    for i, row in enumerate(data["results"]):
        if not isinstance(row, dict):
            errors.append(f"results[{i}] is not an object")
            continue
        for field in RESULT_FIELDS:
            if field not in row:
                errors.append(f"results[{i}] missing field {field!r}")
        name = row.get("name")
        if isinstance(name, str):
            names.append(name)
        for field in ("mean_ns", "p50_ns", "p95_ns"):
            v = row.get(field)
            if isinstance(v, (int, float)) and v <= 0:
                errors.append(f"results[{i}] ({name}): {field} must be positive, got {v}")

    for expected in EXPECTED_RESULTS:
        if expected not in names:
            errors.append(f"missing expected result row: {expected!r}")
    for key in EXPECTED_DERIVED:
        if key not in data["derived"]:
            errors.append(f"missing expected derived key: {key!r}")
    return errors


def main(argv: list[str]) -> int:
    args = [a for a in argv if a != "--allow-placeholder"]
    allow_placeholder = len(args) != len(argv)
    root = Path(__file__).resolve().parent.parent
    path = Path(args[0]) if args else root / "BENCH_sched.json"
    errors = check(path, allow_placeholder)
    if errors:
        for e in errors:
            print(f"check_bench_schema: {e}", file=sys.stderr)
        return 1
    print(f"check_bench_schema: {path} OK")
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
