#!/usr/bin/env python3
"""Validate a detlint_report.json produced by `cargo run -p detlint --
--json <path>` (see rust/detlint/src/report.rs for the writer).

Usage:
    check_detlint_schema.py [--allow-unwaived] [PATH]

PATH defaults to detlint_report.json at the repo root. By default the report
must be *clean*: zero unwaived violations (the CI gate). `--allow-unwaived`
validates structure only, for inspecting a red report without failing twice.

Exit status 0 on success, 1 with per-problem messages otherwise.
"""

import json
import sys
from pathlib import Path

EXPECTED_VERSION = 1

EXPECTED_RULES = [
    "r1-no-wall-clock",
    "r2-no-hash-order",
    "r3-journal-completeness",
    "r4-no-panic-surface",
    "r5-seeded-rng-only",
]

TOP_LEVEL_KEYS = ["version", "root", "files_scanned", "rules", "violations", "summary"]

VIOLATION_FIELDS = ["rule", "file", "line", "message", "waived"]

SUMMARY_KEYS = ["total", "waived", "unwaived", "by_rule"]


def check(path: Path, allow_unwaived: bool) -> list[str]:
    errors: list[str] = []
    try:
        data = json.loads(path.read_text())
    except (OSError, json.JSONDecodeError) as e:
        return [f"{path}: unreadable or invalid JSON: {e}"]

    for key in TOP_LEVEL_KEYS:
        if key not in data:
            errors.append(f"missing top-level key: {key!r}")
    if errors:
        return errors

    if data["version"] != EXPECTED_VERSION:
        errors.append(f"version is {data['version']!r}, expected {EXPECTED_VERSION}")
    if not isinstance(data["files_scanned"], int) or data["files_scanned"] <= 0:
        errors.append(f"files_scanned must be a positive int, got {data['files_scanned']!r}")
    if not isinstance(data["violations"], list):
        return errors + ["'violations' is not a list"]
    if not isinstance(data["summary"], dict):
        return errors + ["'summary' is not an object"]

    for rule in EXPECTED_RULES:
        if rule not in data["rules"]:
            errors.append(f"rule {rule!r} missing from enabled set — CI must run all five")

    waived = 0
    for i, v in enumerate(data["violations"]):
        if not isinstance(v, dict):
            errors.append(f"violations[{i}] is not an object")
            continue
        for field in VIOLATION_FIELDS:
            if field not in v:
                errors.append(f"violations[{i}] missing field {field!r}")
        if v.get("waived"):
            waived += 1
            if not v.get("justification"):
                errors.append(
                    f"violations[{i}] ({v.get('file')}:{v.get('line')}): "
                    "waived without a justification"
                )

    summary = data["summary"]
    for key in SUMMARY_KEYS:
        if key not in summary:
            errors.append(f"summary missing key {key!r}")
    if errors:
        return errors

    total = len(data["violations"])
    if summary["total"] != total:
        errors.append(f"summary.total is {summary['total']}, but {total} violations listed")
    if summary["waived"] != waived:
        errors.append(f"summary.waived is {summary['waived']}, but {waived} waived listed")
    if summary["unwaived"] != total - waived:
        errors.append(
            f"summary.unwaived is {summary['unwaived']}, expected {total - waived}"
        )
    by_rule_total = sum(summary["by_rule"].values())
    if by_rule_total != total:
        errors.append(f"summary.by_rule sums to {by_rule_total}, expected {total}")

    if summary["unwaived"] and not allow_unwaived:
        errors.append(
            f"{summary['unwaived']} unwaived determinism violations — fix them or "
            "add justified `// detlint: allow(…)` waivers (docs/determinism.md)"
        )
    return errors


def main(argv: list[str]) -> int:
    args = [a for a in argv if a != "--allow-unwaived"]
    allow_unwaived = len(args) != len(argv)
    root = Path(__file__).resolve().parent.parent
    path = Path(args[0]) if args else root / "detlint_report.json"
    errors = check(path, allow_unwaived)
    if errors:
        for e in errors:
            print(f"check_detlint_schema: {e}", file=sys.stderr)
        return 1
    print(f"check_detlint_schema: {path} OK")
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
