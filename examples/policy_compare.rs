//! Fig. 2-style comparison at one load point: all five paper systems plus
//! the AugServe-style `adaptive` scheduler on the same trace, across all
//! four model setups.
//!
//! ```sh
//! cargo run --release --example policy_compare -- [--rate 2.0] [--requests 200]
//! ```

use anyhow::Result;
use infercept::cmds::sim_run_once;
use infercept::coordinator::policy::Policy;
use infercept::sim::SimModelSpec;
use infercept::util::cli::Args;
use infercept::workload::{WorkloadGen, WorkloadKind};

fn main() -> Result<()> {
    let args = Args::from_env(&[])?;
    let rate = args.f64_or("rate", 2.0)?;
    let n = args.usize_or("requests", 200)?;
    let seed = args.u64_or("seed", 42)?;

    for spec in [
        SimModelSpec::gptj_6b(),
        SimModelSpec::vicuna_13b(),
        SimModelSpec::vicuna_13b_tp2(),
        SimModelSpec::llama3_70b_tp4(),
    ] {
        println!("\n=== {} @ {rate} req/s, {n} requests ===", spec.name);
        let trace = WorkloadGen::new(WorkloadKind::Mixed, seed)
            .with_ctx_scale(1.0, spec.max_seq_tokens.min(spec.gpu_blocks * spec.block_size / 4))
            .generate(n, rate);
        let mut base: Option<f64> = None;
        for policy in Policy::fig2_set().into_iter().chain([Policy::adaptive()]) {
            let rep = sim_run_once(&spec, policy, &trace, seed)?;
            let lat = rep.normalized_latency_ms();
            if rep.policy == "vllm" {
                base = Some(lat);
            }
            let speedup =
                base.map(|b| format!("{:5.2}x", b / lat)).unwrap_or_else(|| "  1.00x".into());
            println!("  {} | vs vLLM {speedup}", rep.summary_line());
        }
    }
    Ok(())
}
