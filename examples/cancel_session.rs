//! Session-lifecycle quickstart: abort, deadline, and backpressure against
//! scripted background load.
//!
//! Three things bound a session's lifetime in the serving front:
//!
//!  * a **client abort** (`SessionHandle::cancel`) tears the session out of
//!    whatever state it is in and frees its KV context immediately;
//!  * an **interception deadline** (`--external-timeout` semantics:
//!    `EngineConfig::external_timeout_us`) reclaims a session whose client
//!    never answers — without it, one abandoned session anchors the dense
//!    scheduler tables for the rest of the run;
//!  * **submit backpressure** (`EngineConfig::max_live_sessions`) rejects
//!    new sessions with a typed, retryable error instead of admitting
//!    unboundedly.
//!
//! ```sh
//! cargo run --release --example cancel_session
//! ```

use infercept::prelude::*;
use infercept::workload::{Interception, Segment};

/// A chat turn the client is expected to answer.
fn chat_script() -> RequestScript {
    RequestScript {
        kind: AugmentKind::Chatbot,
        prompt_tokens: 96,
        segments: vec![
            Segment {
                gen_tokens: 48,
                interception: Some(Interception {
                    kind: AugmentKind::Chatbot,
                    duration_us: 28_600_000,
                    ret_tokens: 24,
                }),
            },
            Segment { gen_tokens: 32, interception: None },
        ],
    }
}

fn main() -> anyhow::Result<()> {
    // 1. An InferCept engine with a 5 s (engine-clock) interception deadline.
    let spec = SimModelSpec::gptj_6b();
    let mut cfg = EngineConfig::for_sim(&spec, Policy::infercept());
    cfg.external_timeout_us = 5_000_000;
    let mut front = EngineFront::new(Box::new(SimBackend::new(spec)), cfg);

    // 2. Scripted background load rides along through the same front.
    for tr in WorkloadGen::new(WorkloadKind::Mixed, 42).generate(30, 4.0) {
        front.submit_detached(SessionSpec::scripted(tr.script.clone(), tr.arrival_us))?;
    }

    // 3. Two interactive chat sessions: one the client will abort once it
    //    gets control (per-session override: never time out), one simply
    //    abandoned — the engine's 5 s deadline reclaims it mid-run, while
    //    the scripted load is still flowing.
    let aborted =
        front.submit(SessionSpec::interactive(chat_script()).with_external_timeout(0))?;
    let abandoned = front.submit(SessionSpec::interactive(chat_script()))?;
    println!(
        "sessions {} (will be aborted) and {} (will be abandoned) \
         alongside 30 scripted requests\n",
        aborted.id(),
        abandoned.id()
    );

    let mut aborted_yet = false;
    loop {
        match front.run_until_blocked()? {
            FrontStatus::Drained => break,
            FrontStatus::AwaitingClient => {
                if !aborted_yet {
                    // The client changed its mind: tear the first session
                    // down. The second is never answered — re-entering the
                    // pump lets the engine jump to its deadline.
                    aborted.cancel();
                    aborted_yet = true;
                    println!(
                        "[{:7.3}s] client aborts session {}",
                        front.engine().now() as f64 / 1e6,
                        aborted.id()
                    );
                }
            }
        }
    }

    // 4. Both sessions ended with a terminal Cancelled event; all of their
    //    GPU/CPU blocks are back in the pools (invariant-checked).
    front.engine().check_invariants()?;
    for (name, handle) in [("aborted", &aborted), ("abandoned", &abandoned)] {
        for ev in handle.drain_events() {
            if let EngineEvent::Cancelled { reason, at, .. } = ev {
                println!(
                    "{name} session {}: cancelled at {:.3}s ({reason:?})",
                    handle.id(),
                    at as f64 / 1e6
                );
            }
        }
    }
    let m = &front.engine().metrics;
    println!(
        "\n{} sessions cancelled, {} interception(s) timed out, \
         {} of {} requests completed",
        m.sessions_cancelled,
        m.interceptions_timed_out,
        m.records.iter().filter(|r| r.finished_at.is_some()).count(),
        m.records.len(),
    );

    // 5. Backpressure: a front bounded to the sessions already served
    //    rejects a new one with a typed, retryable error.
    let spec = SimModelSpec::gptj_6b();
    let mut bounded_cfg = EngineConfig::for_sim(&spec, Policy::infercept());
    bounded_cfg.max_live_sessions = 1;
    let mut bounded = EngineFront::new(Box::new(SimBackend::new(spec)), bounded_cfg);
    let _first = bounded.submit(SessionSpec::interactive(chat_script()))?;
    match bounded.submit(SessionSpec::interactive(chat_script())) {
        Err(SubmitError::AtCapacity { live, waiting, max_live, max_waiting }) => {
            // Both depths and both caps arrive with the error, so a real
            // client can back off in an informed way (e.g. wait until
            // `live` drops well below `max_live`) instead of blind-retrying.
            println!(
                "\nbackpressure: second submit rejected \
                 ({live}/{max_live} live, {waiting}/{max_waiting} waiting)"
            );
        }
        other => anyhow::bail!("expected AtCapacity, got {other:?}"),
    }
    Ok(())
}
