//! Reproduce Table 1 (+ Fig. 4/5 CDFs with --cdf) from the augmentation
//! trace generator.
//!
//! ```sh
//! cargo run --release --example table1_properties -- [--cdf] [--requests 2000]
//! ```

use anyhow::Result;
use infercept::cmds::table1;
use infercept::util::cli::Args;

fn main() -> Result<()> {
    let args = Args::from_env(&["cdf"])?;
    table1::run(&args)
}
