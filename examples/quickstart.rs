//! Quickstart: serve a small augmented-LLM workload with InferCept on the
//! simulated A100 backend and print the headline metrics.
//!
//! ```sh
//! cargo run --release --example quickstart
//! ```

use infercept::prelude::*;

fn main() -> anyhow::Result<()> {
    // 1. Pick a model/GPU setup (GPT-J-6B on one A100) and the policy.
    let spec = SimModelSpec::gptj_6b();
    let cfg = EngineConfig::for_sim(&spec, Policy::infercept());

    // 2. Generate a mixed augmented workload: math, QA, virtual
    //    environments, chatbot, image generation, TTS (Table 1 marginals).
    let trace = WorkloadGen::new(WorkloadKind::Mixed, 42).generate(100, 2.0);

    // 3. Serve it.
    let mut engine = Engine::new(Box::new(SimBackend::new(spec)), cfg);
    let report = engine.run_trace(&trace)?;

    println!("{}", report.summary_line());
    println!(
        "normalized latency: {:.2} ms/token | throughput: {:.2} req/s | \
         TTFT p50: {:.0} ms | GPU waste: {:.1} GB·s",
        report.normalized_latency_ms(),
        report.throughput_rps(),
        report.median_ttft_ms(),
        report.waste.total(),
    );

    // 4. Compare against vanilla vLLM (Discard) on the same trace.
    let spec = SimModelSpec::gptj_6b();
    let cfg = EngineConfig::for_sim(&spec, Policy::vllm());
    let mut engine = Engine::new(Box::new(SimBackend::new(spec)), cfg);
    let vllm = engine.run_trace(&trace)?;
    println!(
        "vs vLLM: {:.2} ms/token ({:.2}x), waste {:.1} GB·s ({:.1}x)",
        vllm.normalized_latency_ms(),
        vllm.normalized_latency_ms() / report.normalized_latency_ms(),
        vllm.waste.total(),
        vllm.waste.total() / report.waste.total().max(1e-9),
    );
    Ok(())
}
