//! END-TO-END VALIDATION DRIVER (EXPERIMENTS.md §E2E).
//!
//! Loads a real (mini) model's AOT artifacts, compiles them on the PJRT CPU
//! client, and serves a mixed augmented workload with *real* batched
//! forward passes through the Pallas-kernel HLO — proving all three layers
//! compose: L1 Pallas paged attention → L2 JAX model → L3 Rust coordinator.
//!
//! ```sh
//! make artifacts   # once
//! cargo run --release --example serve_mixed -- [--requests 12] [--policy infercept]
//! ```

use anyhow::Result;
use infercept::cmds::serve;
use infercept::util::cli::Args;

fn main() -> Result<()> {
    let mut args = Args::from_env(&[])?;
    // Defaults tuned for a quick but meaningful run; override on the CLI.
    args.options.entry("requests".into()).or_insert("12".into());
    args.options.entry("policy".into()).or_insert("infercept".into());
    args.options.entry("rate".into()).or_insert("2.0".into());
    serve::run(&args)
}
