//! Cross-session prefix sharing: N sessions with a common prompt admit
//! with ~1 physical copy of the prefix instead of N.
//!
//! Sessions submitted with the same [`SessionSpec::with_shared_prefix`] key
//! fork from the key's most recent session at admission: the block-aligned
//! GPU-resident prompt prefix is aliased (refcounted, copy-on-write), so
//! the later sessions neither re-prefill nor hold their own copy. Each
//! successful fork surfaces as an [`EngineEvent::PrefixHit`] right after
//! `Admitted`.
//!
//! ```sh
//! cargo run --release --example shared_prefix
//! ```

use infercept::prelude::*;
use infercept::workload::Segment;

fn main() -> anyhow::Result<()> {
    let spec = SimModelSpec::gptj_6b();
    let cfg = EngineConfig::for_sim(&spec, Policy::infercept());
    let bs = cfg.block_size as u64;
    let mut front = EngineFront::new(Box::new(SimBackend::new(spec)), cfg);

    // One shared 512-token system prompt (an FAQ preamble, say), eight
    // sessions arriving 50 ms apart — close enough that the prefix is
    // still GPU-resident when each successor lands.
    let prompt: Vec<u32> = (0..512u32).map(|i| (i * 31) % 30_000).collect();
    let script = RequestScript {
        kind: AugmentKind::Qa,
        prompt_tokens: prompt.len() as u32,
        segments: vec![Segment { gen_tokens: 48, interception: None }],
    };

    let n = 8;
    let mut handles = Vec::new();
    for i in 0..n {
        let spec = SessionSpec::scripted(script.clone(), i as u64 * 50_000)
            .with_prompt(prompt.clone())
            .with_shared_prefix("faq-preamble");
        match front.submit(spec) {
            Ok(h) => handles.push(h),
            Err(e) => return Err(e.into()),
        }
    }

    match front.run_until_blocked()? {
        FrontStatus::Drained => {}
        FrontStatus::AwaitingClient => anyhow::bail!("scripted sessions cannot block"),
    }

    for h in &handles {
        for ev in h.drain_events() {
            if let EngineEvent::PrefixHit { req, shared_tokens, at } = ev {
                println!(
                    "session {req}: prefix hit — {shared_tokens} of {} prompt tokens \
                     aliased at t={:.1} ms",
                    prompt.len(),
                    at as f64 / 1e3,
                );
            }
        }
    }

    let report = front.report();
    println!(
        "\n{n} sessions, {} prefix hits: peak {} physical GPU blocks shared, \
         {} copy-on-write copies",
        report.prefix_hits, report.blocks_shared, report.cow_copies,
    );
    println!(
        "without sharing, the same admissions would have prefilled and held \
         ~{} extra blocks of duplicate prefix KV",
        report.prefix_hits * (prompt.len() as u64 / bs),
    );
    println!("{}", report.summary_line());
    Ok(())
}
