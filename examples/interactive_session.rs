//! Interactive session quickstart: drive the intercept-first serving API
//! with an externally-resumed chat interception.
//!
//! A chat turn is an interception the *client* resolves: the engine pauses
//! the session (context preserved / swapped per policy — not thrown away),
//! streams an `Intercepted` event, and resumes only when the client calls
//! `resume_with` with the human's next message. Scripted background load
//! runs concurrently through the same front.
//!
//! ```sh
//! cargo run --release --example interactive_session
//! ```

use infercept::prelude::*;
use infercept::workload::{Interception, Segment};

/// A 3-turn chat: generate a reply, wait for the human, twice; then close.
fn chat_script() -> RequestScript {
    let turn = |gen_tokens| Segment {
        gen_tokens,
        interception: Some(Interception {
            kind: AugmentKind::Chatbot,
            duration_us: 28_600_000, // Table 1: the human's expected latency
            ret_tokens: 24,
        }),
    };
    RequestScript {
        kind: AugmentKind::Chatbot,
        prompt_tokens: 96,
        segments: vec![turn(48), turn(64), Segment { gen_tokens: 32, interception: None }],
    }
}

fn main() -> anyhow::Result<()> {
    // 1. An InferCept engine on the simulated A100, behind the session front.
    let spec = SimModelSpec::gptj_6b();
    let cfg = EngineConfig::for_sim(&spec, Policy::infercept());
    let mut front = EngineFront::new(Box::new(SimBackend::new(spec)), cfg);

    // 2. Ambient scripted load (timer-resolved, as in the paper's traces).
    for tr in WorkloadGen::new(WorkloadKind::Mixed, 42).generate(40, 4.0) {
        front.submit_detached(SessionSpec::scripted(tr.script.clone(), tr.arrival_us))?;
    }

    // 3. The interactive chat session: interceptions come back to us.
    let session = front.submit(SessionSpec::interactive(chat_script()))?;
    println!("chat session {} submitted alongside 40 scripted requests\n", session.id());

    let mut turn = 0usize;
    loop {
        match front.run_until_blocked()? {
            FrontStatus::Drained => break,
            FrontStatus::AwaitingClient => {
                // Catch up on the session's stream, then answer the pause.
                let events = session.drain_events();
                let tokens = events.iter().filter(|e| e.tag() == "token").count();
                let paused = events.iter().any(|e| e.tag() == "intercepted");
                println!(
                    "[{:8.3}s] assistant streamed {tokens} tokens, waiting on the human",
                    front.engine().now() as f64 / 1e6
                );
                assert!(paused, "AwaitingClient implies an Intercepted event");
                turn += 1;
                // The human reads and types for ~2 s of engine time, then
                // sends the next message (24 synthetic prompt tokens).
                let reply: Vec<u32> = (0..24).map(|i| 1000 + turn as u32 * 100 + i).collect();
                session.resume_with_after(reply, 2_000_000);
            }
        }
    }

    // 4. The pause cost nothing but held memory: no recomputation happened
    //    for the chat session under InferCept's min-waste schedule.
    for ev in session.drain_events() {
        if let EngineEvent::Finished { record, .. } = ev {
            println!(
                "\nchat finished: {} output tokens over {} interceptions, \
                 {:.1}s paused on the human",
                record.output_tokens,
                record.interceptions,
                record.intercepted_us as f64 / 1e6,
            );
        }
    }
    let m = &front.engine().metrics;
    let rep = front.report();
    println!("{}", rep.summary_line());
    println!(
        "dispositions: {} preserve / {} discard / {} swap  ({} of {} interceptions \
         externally resolved)",
        m.preserve_decisions,
        m.discard_decisions,
        m.swap_decisions,
        m.external_interceptions,
        m.interceptions_dispatched,
    );
    Ok(())
}
