//! Fig. 1 companion: the waste anatomy of one intercepted request under
//! Discard / Preserve / Swap / chunked-Discard, straight from the paper's
//! equations (§3.2, §4.2), swept over context length and interception time.
//!
//! ```sh
//! cargo run --release --example waste_anatomy
//! ```

use infercept::coordinator::waste::{
    min_waste, waste_chunked_discard, waste_discard, waste_preserve, waste_swap, WasteInputs,
};
use infercept::sim::SimModelSpec;

fn main() {
    let spec = SimModelSpec::gptj_6b();
    let profile = &spec.profile;
    let sync_swap = spec.swap_model(false);

    println!("GPU-memory waste (GB·s) per interception — GPT-J-6B / A100 cost model");
    println!("(running batch: 10k context tokens)\n");
    println!(
        "{:>8} {:>12} | {:>12} {:>12} {:>12} {:>12} | {:>10}",
        "ctx", "int-time", "Discard", "Preserve", "Swap", "ChunkedD", "min-waste"
    );
    for ctx in [500usize, 1422, 2185] {
        for int_s in [0.0002f64, 0.09, 0.69, 17.0, 28.6] {
            let w = WasteInputs {
                ctx_tokens: ctx,
                other_tokens: 10_000,
                kv_bytes_per_token: spec.kv_bytes_per_token,
                est_interception_us: int_s * 1e6,
                chunk_tokens: 256,
                running_query: 32,
                running_ctx: 10_000,
            };
            let t_swap = sync_swap.t_swap(ctx);
            let mw = min_waste(profile, &w);
            println!(
                "{:>8} {:>10.4}s | {:>12.2} {:>12.2} {:>12.2} {:>12.2} | {:>10}",
                ctx,
                int_s,
                waste_discard(profile, &w),
                waste_preserve(&w),
                waste_swap(t_swap, &w),
                waste_chunked_discard(profile, &w),
                if mw.prefer_preserve { "preserve" } else { "discard" },
            );
        }
        println!();
    }
    println!(
        "Reading: short automated calls (math 0.2 ms, VE 90 ms) → preserve is ~free;\n\
         human-scale pauses (chat 28.6 s) → holding memory dominates, discard/swap wins.\n\
         Chunked discard ≤ half of Discard's recompute waste (Eq. 4 vs Eq. 1)."
    );
}
