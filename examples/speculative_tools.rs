//! Speculative continuation through tool calls: predict the answer, decode
//! ahead on a copy-on-write branch, verify-or-drop when the real call
//! resolves.
//!
//! Each scripted session generates, fires a 300 ms "math tool" interception
//! that returns 8 tokens, then keeps generating. With speculation enabled
//! ([`SessionSpec::with_speculate`] or `EngineConfig::speculate`) the engine
//! forks the paused context, injects the predicted answer, and lets the
//! branch decode through the pause in the normal batch. The run is repeated
//! without speculation to show what the salvage buys: the speculating run
//! resumes with already-decoded continuation tokens instead of an idle
//! pause.
//!
//! ```sh
//! cargo run --release --example speculative_tools
//! ```

use infercept::prelude::*;
use infercept::util::Micros;
use infercept::workload::{Interception, Segment};

fn script() -> RequestScript {
    RequestScript {
        kind: AugmentKind::Math,
        prompt_tokens: 96,
        segments: vec![
            Segment {
                gen_tokens: 24,
                interception: Some(Interception {
                    kind: AugmentKind::Math,
                    duration_us: 300_000,
                    ret_tokens: 8,
                }),
            },
            Segment { gen_tokens: 160, interception: None },
        ],
    }
}

fn run(speculate: bool) -> anyhow::Result<(RunReport, Vec<String>)> {
    let spec = SimModelSpec::gptj_6b();
    let cfg = EngineConfig::for_sim(&spec, Policy::infercept());
    let vocab = cfg.vocab;
    let mut front = EngineFront::new(Box::new(SimBackend::new(spec)), cfg);
    // The oracle predictor replays the scripted tool answers exactly; swap
    // in `CachedAnswerPredictor` (the default) for the memoize-and-replay
    // strategy, or implement `AnswerPredictor` for a learned one.
    front.engine_mut().set_answer_predictor(Box::new(OraclePredictor::new(vocab)));

    let mut handles = Vec::new();
    for i in 0..4u64 {
        let s = SessionSpec::scripted(script(), i * 40_000).with_speculate(speculate);
        handles.push(front.submit(s)?);
    }
    match front.run_until_blocked()? {
        FrontStatus::Drained => {}
        FrontStatus::AwaitingClient => anyhow::bail!("scripted sessions cannot block"),
    }
    front.engine().check_invariants()?;

    let mut lines = Vec::new();
    for h in &handles {
        for ev in h.drain_events() {
            let ms = |at: Micros| at as f64 / 1e3;
            match ev {
                EngineEvent::SpeculationStarted { req, branch, predicted_tokens, at } => {
                    lines.push(format!(
                        "t={:7.1} ms  session {req}: forked branch {branch}, \
                         injected {predicted_tokens} predicted answer tokens",
                        ms(at),
                    ));
                }
                EngineEvent::SpeculationAccepted { req, branch, salvaged_tokens, at } => {
                    lines.push(format!(
                        "t={:7.1} ms  session {req}: branch {branch} verified — \
                         {salvaged_tokens} tokens salvaged into the session",
                        ms(at),
                    ));
                }
                EngineEvent::SpeculationRejected { req, branch, accepted, at } => {
                    lines.push(format!(
                        "t={:7.1} ms  session {req}: branch {branch} dropped \
                         (prefix match {accepted})",
                        ms(at),
                    ));
                }
                _ => {}
            }
        }
    }
    Ok((front.report(), lines))
}

fn main() -> anyhow::Result<()> {
    let (base, _) = run(false)?;
    let (spec, lines) = run(true)?;

    println!("speculation lifecycle:");
    for l in &lines {
        println!("  {l}");
    }
    println!(
        "\nspeculations: {} started, {} accepted, {} rejected",
        spec.speculations_started, spec.speculations_accepted, spec.speculations_rejected,
    );
    println!(
        "branch tokens: {} decoded ahead, {} salvaged, {} wasted \
         (salvage ratio {:.0}%)",
        spec.speculative_tokens_decoded,
        spec.speculative_tokens_salvaged,
        spec.speculative_tokens_wasted,
        spec.speculation_salvage_ratio() * 100.0,
    );
    println!("\nwithout speculation: {}", base.summary_line());
    println!("with speculation:    {}", spec.summary_line());
    Ok(())
}
